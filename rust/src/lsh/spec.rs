//! Declarative LSH configuration: one plain-data, JSON round-trippable spec
//! drives planner → family → index → coordinator → CLI.
//!
//! The paper's four tensorized families (CP/TT × E2LSH/SRP) plus the naive
//! baselines all share one parameter tuple — family kind, mode dims,
//! projection rank, K hashes per signature, L tables, bucket width w,
//! metric, multiprobe budget, and a seed policy. [`FamilySpec`] captures the
//! per-table part, [`LshSpec`] the whole index (and the serving knobs the
//! coordinator needs), and everything downstream builds *from* the spec:
//!
//! * [`LshSpec::family`] instantiates table `t`'s [`HashFamily`] — it
//!   replaces the hand-rolled `family_builder` closures of
//!   [`IndexConfig`] (which survive only as a deprecated escape hatch that
//!   [`IndexConfig::from_spec`] builds from the spec).
//! * [`LshIndex::from_spec`] / [`ShardedLshIndex::from_spec`] /
//!   [`crate::coordinator::CoordinatorConfig::from_spec`] construct every
//!   layer of the stack from the same value.
//! * [`LshSpec::planned`] wires `lsh::planner`: K and L come from the
//!   classical (R₁, R₂, P₁, P₂) theory, gated by [`validity_report`] so a
//!   dims/rank combination outside the theorems' asymptotic regime is a
//!   typed [`Error::InvalidSpec`] instead of a silent bad index.
//! * [`LshSpec::to_json`] / [`LshSpec::from_json_str`] round-trip through
//!   `util::json` (zero deps), so serving configs are reproducible and the
//!   benches stamp the exact spec into their `BENCH_*.json` reports.
//!
//! The fluent layers on top: [`IndexBuilder`] for offline indexes,
//! [`CoordinatorBuilder`] for the serving pipeline.
//!
//! ```
//! use tensor_lsh::prelude::*;
//!
//! let spec = LshSpec::cosine(FamilyKind::Cp, vec![8, 8, 8], 4, 10, 8);
//! let json = spec.to_json_string();
//! assert_eq!(LshSpec::from_json_str(&json)?, spec);
//! let index = IndexBuilder::new(spec).build()?; // empty LshIndex
//! assert_eq!(index.n_tables(), 8);
//! # Ok::<(), tensor_lsh::Error>(())
//! ```

use super::planner::{plan_parameters, validity_report, LshPlan};
use super::{E2lshHasher, HashFamily, SrpHasher};
use crate::coordinator::{
    Coordinator, CoordinatorConfig, HashBackend, MetricsSnapshot, QueryRequest, QueryResponse,
};
use crate::error::{Error, Result};
use crate::index::{IndexConfig, LshIndex, Metric, ShardedLshIndex};
use crate::projection::{
    CpRademacher, Distribution, GaussianDense, Precision, SparseGaussian, TtRademacher,
};
use crate::stats;
use crate::store::{Residency, Store};
use crate::tensor::AnyTensor;
use crate::util::json::{parse, Json};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Which projection construction a family uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FamilyKind {
    /// CP-format Rademacher projections (Definitions 10/12).
    Cp,
    /// TT-format Rademacher projections (Definitions 11/13).
    Tt,
    /// Dense Gaussian baseline (reshape + E2LSH [11] / SRP [6]).
    Naive,
    /// Sparse sampled-coordinate projections (fast-E2LSH / fast-SRP in the
    /// spirit of FastLSH, arXiv 2309.15479): `O(m)` instead of `O(D)` flops
    /// per hash. `FamilySpec::sample` sets m.
    Sparse,
}

impl FamilyKind {
    /// Parse a family name as it appears in configs and CLI overrides.
    pub fn parse(s: &str) -> Result<FamilyKind> {
        match s {
            "cp" => Ok(FamilyKind::Cp),
            "tt" => Ok(FamilyKind::Tt),
            "naive" => Ok(FamilyKind::Naive),
            "sparse" | "fast" => Ok(FamilyKind::Sparse),
            other => Err(Error::InvalidSpec(format!(
                "unknown family '{other}' (expected one of: cp, tt, naive, sparse)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FamilyKind::Cp => "cp",
            FamilyKind::Tt => "tt",
            FamilyKind::Naive => "naive",
            FamilyKind::Sparse => "sparse",
        }
    }
}

/// Plain-data description of one bank of K hash functions: everything
/// [`FamilySpec::build`] needs except the seed (which the enclosing
/// [`LshSpec`]'s [`SeedPolicy`] supplies per table).
#[derive(Clone, Debug, PartialEq)]
pub struct FamilySpec {
    pub kind: FamilyKind,
    /// Tensor mode dimensions (d₁ … d_N).
    pub dims: Vec<usize>,
    /// Projection tensor rank R (ignored by [`FamilyKind::Naive`]).
    pub rank: usize,
    /// Hashes per table signature.
    pub k: usize,
    /// Discretizer selector: Euclidean ⇒ E2LSH floors, Cosine ⇒ SRP signs.
    pub metric: Metric,
    /// E2LSH bucket width (used only under the Euclidean metric).
    pub w: f64,
    /// Kernel precision for the hash path: [`Precision::F64`] (default) is
    /// the bit-exact reference, [`Precision::F32`] the SIMD-friendly fast
    /// path (EXPERIMENTS.md §Precision).
    pub precision: Precision,
    /// Coordinates sampled per hash (`m`) by [`FamilyKind::Sparse`];
    /// `0` = auto (`D/4`, at least 1). Ignored by the other kinds, like
    /// `rank` is by [`FamilyKind::Naive`].
    pub sample: usize,
}

impl FamilySpec {
    /// SRP family over the cosine metric.
    pub fn srp(kind: FamilyKind, dims: Vec<usize>, rank: usize, k: usize) -> FamilySpec {
        FamilySpec {
            kind,
            dims,
            rank,
            k,
            metric: Metric::Cosine,
            w: 4.0,
            precision: Precision::F64,
            sample: 0,
        }
    }

    /// E2LSH family over the Euclidean metric with bucket width `w`.
    pub fn e2lsh(kind: FamilyKind, dims: Vec<usize>, rank: usize, k: usize, w: f64) -> FamilySpec {
        FamilySpec {
            kind,
            dims,
            rank,
            k,
            metric: Metric::Euclidean,
            w,
            precision: Precision::F64,
            sample: 0,
        }
    }

    /// Select the kernel precision (builder style).
    #[must_use]
    pub fn with_precision(mut self, precision: Precision) -> FamilySpec {
        self.precision = precision;
        self
    }

    /// Set the sparse family's samples-per-hash `m` (builder style).
    #[must_use]
    pub fn with_sample(mut self, sample: usize) -> FamilySpec {
        self.sample = sample;
        self
    }

    /// The sparse family's effective samples per hash: `sample`, or the
    /// `D/4` auto default (≥ 1) when unset. The auto choice keeps a 4×
    /// per-hash FLOP cut while sampling enough coordinates for the
    /// collision laws to hold at the shapes the tests pin.
    pub fn sparse_m(&self) -> usize {
        if self.sample > 0 {
            self.sample
        } else {
            (self.dims.iter().product::<usize>() / 4).max(1)
        }
    }

    /// Numeric validation (typed errors instead of downstream panics).
    pub fn validate(&self) -> Result<()> {
        if self.dims.is_empty() {
            return Err(Error::InvalidSpec("dims must not be empty".into()));
        }
        if let Some(&d) = self.dims.iter().find(|&&d| d == 0) {
            return Err(Error::InvalidSpec(format!("mode dimension {d} must be ≥ 1")));
        }
        if self.rank == 0 {
            return Err(Error::InvalidSpec("rank must be ≥ 1".into()));
        }
        if self.k == 0 {
            return Err(Error::InvalidSpec("k must be ≥ 1".into()));
        }
        if self.metric == Metric::Euclidean && !(self.w > 0.0 && self.w.is_finite()) {
            return Err(Error::InvalidSpec(format!("w must be > 0 (got {})", self.w)));
        }
        Ok(())
    }

    pub(crate) fn cp_proj(&self, seed: u64, k: usize) -> CpRademacher {
        CpRademacher::generate(seed, &self.dims, self.rank, k, Distribution::Rademacher)
    }

    pub(crate) fn tt_proj(&self, seed: u64, k: usize) -> TtRademacher {
        TtRademacher::generate(seed, &self.dims, self.rank, k, Distribution::Rademacher)
    }

    pub(crate) fn sparse_proj(&self, seed: u64, k: usize) -> SparseGaussian {
        SparseGaussian::generate(seed, &self.dims, self.sparse_m(), k)
    }

    /// Instantiate the family with every projection drawn from `seed`. This
    /// is the single constructor path all eight families share — the
    /// deprecated per-family `*Config::new` shims and the
    /// [`LshSpec::family`] tables both route through it.
    pub fn build(&self, seed: u64) -> Result<Arc<dyn HashFamily>> {
        self.validate()?;
        let p = self.precision;
        Ok(match (self.kind, self.metric) {
            (FamilyKind::Cp, Metric::Cosine) => {
                Arc::new(SrpHasher::wrap(self.cp_proj(seed, self.k), "cp").with_precision(p))
            }
            (FamilyKind::Tt, Metric::Cosine) => {
                Arc::new(SrpHasher::wrap(self.tt_proj(seed, self.k), "tt").with_precision(p))
            }
            (FamilyKind::Naive, Metric::Cosine) => Arc::new(
                SrpHasher::wrap(GaussianDense::generate(seed, &self.dims, self.k), "naive")
                    .with_precision(p),
            ),
            (FamilyKind::Sparse, Metric::Cosine) => Arc::new(
                SrpHasher::wrap(self.sparse_proj(seed, self.k), "sparse").with_precision(p),
            ),
            (FamilyKind::Cp, Metric::Euclidean) => Arc::new(
                E2lshHasher::wrap(self.cp_proj(seed, self.k), self.w, seed, "cp")
                    .with_precision(p),
            ),
            (FamilyKind::Tt, Metric::Euclidean) => Arc::new(
                E2lshHasher::wrap(self.tt_proj(seed, self.k), self.w, seed, "tt")
                    .with_precision(p),
            ),
            (FamilyKind::Naive, Metric::Euclidean) => Arc::new(
                E2lshHasher::wrap(
                    GaussianDense::generate(seed, &self.dims, self.k),
                    self.w,
                    seed,
                    "naive",
                )
                .with_precision(p),
            ),
            (FamilyKind::Sparse, Metric::Euclidean) => Arc::new(
                E2lshHasher::wrap(self.sparse_proj(seed, self.k), self.w, seed, "sparse")
                    .with_precision(p),
            ),
        })
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("kind".to_string(), Json::Str(self.kind.name().into()));
        m.insert(
            "dims".to_string(),
            Json::Arr(self.dims.iter().map(|&d| Json::Num(d as f64)).collect()),
        );
        m.insert("rank".to_string(), Json::Num(self.rank as f64));
        m.insert("k".to_string(), Json::Num(self.k as f64));
        m.insert("metric".to_string(), Json::Str(self.metric.name().into()));
        m.insert("w".to_string(), Json::Num(self.w));
        m.insert("precision".to_string(), Json::Str(self.precision.name().into()));
        m.insert("sample".to_string(), Json::Num(self.sample as f64));
        Json::Obj(m)
    }

    pub fn from_json(v: &Json) -> Result<FamilySpec> {
        reject_unknown(
            v,
            &["kind", "dims", "rank", "k", "metric", "w", "precision", "sample"],
            "family",
        )?;
        let dims = v
            .get("dims")?
            .as_arr()?
            .iter()
            .map(Json::as_usize)
            .collect::<Result<Vec<usize>>>()?;
        let obj = v.as_obj()?;
        Ok(FamilySpec {
            kind: FamilyKind::parse(v.get("kind")?.as_str()?)?,
            dims,
            rank: v.get("rank")?.as_usize()?,
            k: v.get("k")?.as_usize()?,
            metric: Metric::parse(v.get("metric")?.as_str()?)?,
            w: v.get("w")?.as_f64()?,
            // Hand-written specs may omit the PR-7 fields: f64 reference
            // precision and auto sampling are the historical behavior.
            precision: match obj.get("precision") {
                Some(p) => Precision::parse(p.as_str()?)?,
                None => Precision::F64,
            },
            sample: match obj.get("sample") {
                Some(n) => n.as_usize()?,
                None => 0,
            },
        })
    }
}

/// How per-table seeds derive from one master seed: table `t` hashes with
/// `base + stride·t` (wrapping). Serializable, unlike a closure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeedPolicy {
    pub base: u64,
    pub stride: u64,
}

impl Default for SeedPolicy {
    /// Stride 1000 — the spacing the bench harness has always used, so
    /// spec-built indexes are bit-identical to the historical construction.
    fn default() -> Self {
        SeedPolicy { base: 42, stride: 1000 }
    }
}

impl SeedPolicy {
    pub fn new(base: u64, stride: u64) -> Self {
        SeedPolicy { base, stride }
    }

    /// The seed table `t` draws its projections (and E2LSH offsets) from.
    pub fn table_seed(&self, table: usize) -> u64 {
        self.base.wrapping_add(self.stride.wrapping_mul(table as u64))
    }

    fn to_json(self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("base".to_string(), Json::Num(self.base as f64));
        m.insert("stride".to_string(), Json::Num(self.stride as f64));
        Json::Obj(m)
    }

    fn from_json(v: &Json) -> Result<SeedPolicy> {
        reject_unknown(v, &["base", "stride"], "seeds")?;
        Ok(SeedPolicy { base: as_u64(v.get("base")?)?, stride: as_u64(v.get("stride")?)? })
    }
}

/// Optional durable-store configuration: where the serving stack snapshots
/// the index ([`crate::store::Store`]), how often the WAL checkpoints, and
/// when churn triggers an arena-reclaiming compaction.
#[derive(Clone, Debug, PartialEq)]
pub struct StoreSpec {
    /// Store directory (snapshot generations + `wal.log`).
    pub dir: String,
    /// Compact (fresh snapshot + WAL truncation) automatically after this
    /// many logged mutations; 0 = manual compaction only.
    pub checkpoint_every: usize,
    /// Dead-fraction compaction trigger: once this fraction of slots is
    /// tombstoned by deletes, the next checkpoint reclaims them (arena +
    /// bucket rewrite). 0 disables the trigger (manual compaction still
    /// reclaims). Must be in `[0, 1)`.
    pub compact_dead_fraction: f64,
    /// Per-shard residency policy applied when the store opens: keep shards
    /// fully in RAM (`Resident`, the default), page buckets/items on demand
    /// through the hot-bucket LRU (`Paged`), or pick per shard by segment
    /// size (`Auto`). See [`crate::store::Residency`].
    pub residency: Residency,
}

impl StoreSpec {
    pub fn new(dir: impl Into<String>) -> StoreSpec {
        StoreSpec {
            dir: dir.into(),
            checkpoint_every: 0,
            compact_dead_fraction: 0.0,
            residency: Residency::Resident,
        }
    }

    pub fn with_checkpoint_every(mut self, n: usize) -> StoreSpec {
        self.checkpoint_every = n;
        self
    }

    pub fn with_compact_dead_fraction(mut self, f: f64) -> StoreSpec {
        self.compact_dead_fraction = f;
        self
    }

    pub fn with_residency(mut self, residency: Residency) -> StoreSpec {
        self.residency = residency;
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.dir.is_empty() {
            return Err(Error::InvalidSpec("store dir must not be empty".into()));
        }
        if !self.compact_dead_fraction.is_finite()
            || self.compact_dead_fraction < 0.0
            || self.compact_dead_fraction >= 1.0
        {
            return Err(Error::InvalidSpec(format!(
                "store compact_dead_fraction must be in [0, 1), got {}",
                self.compact_dead_fraction
            )));
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("dir".to_string(), Json::Str(self.dir.clone()));
        m.insert(
            "checkpoint_every".to_string(),
            Json::Num(self.checkpoint_every as f64),
        );
        // Emitted only when armed: specs written before the knob existed
        // stay byte-identical through a round-trip.
        if self.compact_dead_fraction != 0.0 {
            m.insert(
                "compact_dead_fraction".to_string(),
                Json::Num(self.compact_dead_fraction),
            );
        }
        // Same omit-when-default discipline: specs written before residency
        // tiering existed stay byte-identical through a round-trip.
        if self.residency != Residency::Resident {
            m.insert("residency".to_string(), Json::Str(self.residency.name()));
        }
        Json::Obj(m)
    }

    fn from_json(v: &Json) -> Result<StoreSpec> {
        reject_unknown(
            v,
            &["dir", "checkpoint_every", "compact_dead_fraction", "residency"],
            "store",
        )?;
        Ok(StoreSpec {
            dir: v.get("dir")?.as_str()?.to_string(),
            checkpoint_every: match v.as_obj()?.get("checkpoint_every") {
                Some(n) => n.as_usize()?,
                None => 0,
            },
            compact_dead_fraction: match v.as_obj()?.get("compact_dead_fraction") {
                Some(n) => n.as_f64()?,
                None => 0.0,
            },
            residency: match v.as_obj()?.get("residency") {
                Some(s) => Residency::parse(s.as_str()?)?,
                None => Residency::Resident,
            },
        })
    }
}

/// Optional wire-listener configuration: where `tensorlsh serve` binds its
/// framed TCP front end ([`crate::net::Server`]) and the connection-level
/// limits it enforces.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NetSpec {
    /// Listen address, e.g. `"127.0.0.1:7878"` (`:0` picks a free port).
    pub addr: String,
    /// Concurrent connections before new sockets are shed with `Busy`.
    pub max_conns: usize,
    /// Per-connection idle/read budget in milliseconds.
    pub read_timeout_ms: u64,
    /// Per-connection write budget in milliseconds.
    pub write_timeout_ms: u64,
    /// Admission-control cap on pipeline in-flight depth; searches past it
    /// are refused with `Busy`.
    pub max_inflight: usize,
}

impl Default for NetSpec {
    fn default() -> Self {
        NetSpec {
            addr: "127.0.0.1:7878".to_string(),
            max_conns: 64,
            read_timeout_ms: 30_000,
            write_timeout_ms: 10_000,
            max_inflight: 1024,
        }
    }
}

impl NetSpec {
    pub fn new(addr: impl Into<String>) -> NetSpec {
        NetSpec { addr: addr.into(), ..NetSpec::default() }
    }

    pub fn validate(&self) -> Result<()> {
        if self.addr.is_empty() {
            return Err(Error::InvalidSpec("listen addr must not be empty".into()));
        }
        if self.max_conns == 0 {
            return Err(Error::InvalidSpec("listen max_conns must be ≥ 1".into()));
        }
        if self.max_inflight == 0 {
            return Err(Error::InvalidSpec("listen max_inflight must be ≥ 1".into()));
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("addr".to_string(), Json::Str(self.addr.clone()));
        m.insert("max_conns".to_string(), Json::Num(self.max_conns as f64));
        m.insert(
            "read_timeout_ms".to_string(),
            Json::Num(self.read_timeout_ms as f64),
        );
        m.insert(
            "write_timeout_ms".to_string(),
            Json::Num(self.write_timeout_ms as f64),
        );
        m.insert("max_inflight".to_string(), Json::Num(self.max_inflight as f64));
        Json::Obj(m)
    }

    fn from_json(v: &Json) -> Result<NetSpec> {
        reject_unknown(
            v,
            &["addr", "max_conns", "read_timeout_ms", "write_timeout_ms", "max_inflight"],
            "listen",
        )?;
        let defaults = NetSpec::default();
        let obj = v.as_obj()?;
        Ok(NetSpec {
            addr: v.get("addr")?.as_str()?.to_string(),
            max_conns: match obj.get("max_conns") {
                Some(n) => n.as_usize()?,
                None => defaults.max_conns,
            },
            read_timeout_ms: match obj.get("read_timeout_ms") {
                Some(n) => as_u64(n)?,
                None => defaults.read_timeout_ms,
            },
            write_timeout_ms: match obj.get("write_timeout_ms") {
                Some(n) => as_u64(n)?,
                None => defaults.write_timeout_ms,
            },
            max_inflight: match obj.get("max_inflight") {
                Some(n) => n.as_usize()?,
                None => defaults.max_inflight,
            },
        })
    }
}

/// Serving-side knobs the coordinator and sharded index read off the spec.
#[derive(Clone, Debug, PartialEq)]
pub struct ServingSpec {
    /// Index shards (re-rank fan-out width).
    pub shards: usize,
    /// Coordinator re-rank workers.
    pub n_workers: usize,
    /// Dynamic batcher: max queries per hash batch.
    pub max_batch: usize,
    /// Dynamic batcher: batch deadline in microseconds.
    pub max_wait_us: u64,
    /// Optional durable store (`None` = memory-only serving, the default).
    pub store: Option<StoreSpec>,
    /// Optional wire listener (`None` = in-process serving only).
    pub listen: Option<NetSpec>,
    /// Slow-query log threshold in microseconds: queries whose end-to-end
    /// latency reaches this emit a `slow_query` event with the full
    /// [`crate::query::QueryOpts`] and per-stage breakdown. 0 (default)
    /// disables the log.
    pub slow_query_us: u64,
    /// Structured event log threshold (`debug` | `info` | `warn` | `error`
    /// | `off`); parsed with [`crate::obs::Level::parse`] and applied when
    /// serving starts. Default `"warn"`.
    pub log_level: String,
}

impl Default for ServingSpec {
    fn default() -> Self {
        ServingSpec {
            shards: 4,
            n_workers: 4,
            max_batch: 64,
            max_wait_us: 500,
            store: None,
            listen: None,
            slow_query_us: 0,
            log_level: "warn".to_string(),
        }
    }
}

impl ServingSpec {
    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            return Err(Error::InvalidSpec("shards must be ≥ 1".into()));
        }
        if self.n_workers == 0 {
            return Err(Error::InvalidSpec("n_workers must be ≥ 1".into()));
        }
        if self.max_batch == 0 {
            return Err(Error::InvalidSpec("max_batch must be ≥ 1".into()));
        }
        if let Some(store) = &self.store {
            store.validate()?;
        }
        if let Some(listen) = &self.listen {
            listen.validate()?;
        }
        crate::obs::Level::parse(&self.log_level)
            .map_err(|_| Error::InvalidSpec(format!(
                "log_level '{}' is not one of debug|info|warn|error|off",
                self.log_level
            )))?;
        Ok(())
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("shards".to_string(), Json::Num(self.shards as f64));
        m.insert("n_workers".to_string(), Json::Num(self.n_workers as f64));
        m.insert("max_batch".to_string(), Json::Num(self.max_batch as f64));
        m.insert("max_wait_us".to_string(), Json::Num(self.max_wait_us as f64));
        m.insert(
            "store".to_string(),
            match &self.store {
                None => Json::Null,
                Some(s) => s.to_json(),
            },
        );
        m.insert(
            "listen".to_string(),
            match &self.listen {
                None => Json::Null,
                Some(l) => l.to_json(),
            },
        );
        // Observability knobs are emitted only when set, so specs written
        // before the knobs existed round-trip byte-identically.
        if self.slow_query_us != 0 {
            m.insert(
                "slow_query_us".to_string(),
                Json::Num(self.slow_query_us as f64),
            );
        }
        if self.log_level != "warn" {
            m.insert("log_level".to_string(), Json::Str(self.log_level.clone()));
        }
        Json::Obj(m)
    }

    fn from_json(v: &Json) -> Result<ServingSpec> {
        reject_unknown(
            v,
            &[
                "shards",
                "n_workers",
                "max_batch",
                "max_wait_us",
                "store",
                "listen",
                "slow_query_us",
                "log_level",
            ],
            "serving",
        )?;
        let defaults = ServingSpec::default();
        Ok(ServingSpec {
            shards: v.get("shards")?.as_usize()?,
            n_workers: v.get("n_workers")?.as_usize()?,
            max_batch: v.get("max_batch")?.as_usize()?,
            max_wait_us: as_u64(v.get("max_wait_us")?)?,
            store: match v.as_obj()?.get("store") {
                None | Some(Json::Null) => None,
                Some(s) => Some(StoreSpec::from_json(s)?),
            },
            listen: match v.as_obj()?.get("listen") {
                None | Some(Json::Null) => None,
                Some(l) => Some(NetSpec::from_json(l)?),
            },
            slow_query_us: match v.as_obj()?.get("slow_query_us") {
                Some(n) => as_u64(n)?,
                None => defaults.slow_query_us,
            },
            log_level: match v.as_obj()?.get("log_level") {
                Some(l) => l.as_str()?.to_string(),
                None => defaults.log_level,
            },
        })
    }
}

/// The whole index, declaratively: per-table family template, table count,
/// multiprobe budget, seed policy, banding flag, serving knobs. One value
/// of this type drives every constructor in the crate.
#[derive(Clone, Debug, PartialEq)]
pub struct LshSpec {
    pub family: FamilySpec,
    /// Number of tables L.
    pub l: usize,
    /// Multiprobe extra probes per table (0 = exact bucket only).
    pub probes: usize,
    /// LSH banding: when true, one `K·L`-wide projection bank seeded at
    /// `seeds.base` is generated and table `t` hashes with codes
    /// `[t·K, (t+1)·K)` of it — the layout the PJRT artifacts emit, so the
    /// native index buckets identically to artifact-hashed signatures.
    /// `seeds.stride` is unused in this mode.
    pub banded: bool,
    pub seeds: SeedPolicy,
    pub serving: ServingSpec,
}

impl LshSpec {
    /// Spec with default probes (0), seeds, serving knobs.
    pub fn new(family: FamilySpec, l: usize) -> LshSpec {
        LshSpec {
            family,
            l,
            probes: 0,
            banded: false,
            seeds: SeedPolicy::default(),
            serving: ServingSpec::default(),
        }
    }

    /// Cosine (SRP) index spec.
    pub fn cosine(kind: FamilyKind, dims: Vec<usize>, rank: usize, k: usize, l: usize) -> LshSpec {
        LshSpec::new(FamilySpec::srp(kind, dims, rank, k), l)
    }

    /// Euclidean (E2LSH) index spec with bucket width `w`.
    pub fn euclidean(
        kind: FamilyKind,
        dims: Vec<usize>,
        rank: usize,
        k: usize,
        l: usize,
        w: f64,
    ) -> LshSpec {
        LshSpec::new(FamilySpec::e2lsh(kind, dims, rank, k, w), l)
    }

    // -- fluent setters ----------------------------------------------------

    pub fn with_k(mut self, k: usize) -> LshSpec {
        self.family.k = k;
        self
    }

    pub fn with_tables(mut self, l: usize) -> LshSpec {
        self.l = l;
        self
    }

    pub fn with_probes(mut self, probes: usize) -> LshSpec {
        self.probes = probes;
        self
    }

    pub fn with_w(mut self, w: f64) -> LshSpec {
        self.family.w = w;
        self
    }

    /// Select the kernel precision for every table's family
    /// (EXPERIMENTS.md §Precision).
    #[must_use]
    pub fn with_precision(mut self, precision: Precision) -> LshSpec {
        self.family.precision = precision;
        self
    }

    /// Set the sparse family's samples-per-hash `m` (0 = auto `D/4`).
    #[must_use]
    pub fn with_sample(mut self, sample: usize) -> LshSpec {
        self.family.sample = sample;
        self
    }

    pub fn with_seed(mut self, base: u64, stride: u64) -> LshSpec {
        self.seeds = SeedPolicy::new(base, stride);
        self
    }

    pub fn with_banded(mut self, banded: bool) -> LshSpec {
        self.banded = banded;
        self
    }

    pub fn with_serving(mut self, serving: ServingSpec) -> LshSpec {
        self.serving = serving;
        self
    }

    /// Attach a durable store to the serving config (see [`StoreSpec`]).
    pub fn with_store(mut self, store: StoreSpec) -> LshSpec {
        self.serving.store = Some(store);
        self
    }

    /// Attach a wire listener to the serving config (see [`NetSpec`]).
    pub fn with_listen(mut self, listen: NetSpec) -> LshSpec {
        self.serving.listen = Some(listen);
        self
    }

    // -- validation --------------------------------------------------------

    /// Validate every numeric field (typed [`Error::InvalidSpec`] instead
    /// of downstream panics). `from_spec` constructors and JSON parsing all
    /// call this.
    pub fn validate(&self) -> Result<()> {
        self.family.validate()?;
        self.serving.validate()?;
        if self.l == 0 {
            return Err(Error::InvalidSpec("l (tables) must be ≥ 1".into()));
        }
        if !self.banded && self.l > 1 && self.seeds.stride == 0 {
            return Err(Error::InvalidSpec(
                "seed stride 0 with l > 1 would make every table identical".into(),
            ));
        }
        if self.banded && self.family.kind == FamilyKind::Naive {
            return Err(Error::InvalidSpec(
                "banding needs a low-rank bank (cp or tt), not the naive family".into(),
            ));
        }
        // JSON numbers are f64: integers ≥ 2^53 would round-trip lossily,
        // breaking the to_json/from_json identity this type promises.
        for (name, v) in [
            ("seed base", self.seeds.base),
            ("seed stride", self.seeds.stride),
            ("max_wait_us", self.serving.max_wait_us),
        ] {
            if v >= MAX_JSON_INT {
                return Err(Error::InvalidSpec(format!(
                    "{name} {v} does not fit a JSON number exactly (must be < 2^53)"
                )));
            }
        }
        Ok(())
    }

    // -- planner wiring ----------------------------------------------------

    /// Classical (K, L) planning for this spec's family and metric over a
    /// corpus of `n` items with failure budget `delta`.
    ///
    /// Threshold semantics follow the metric: under Euclidean, `r1` is the
    /// near radius and the far radius is `c·r1` (approximation factor
    /// `c > 1`); under cosine, `r1` is the near *similarity* and `c` the far
    /// similarity (`-1 < c < r1 ≤ 1`).
    pub fn plan(&self, n: usize, r1: f64, c: f64, delta: f64) -> Result<LshPlan> {
        self.family.validate()?;
        if n < 2 {
            return Err(Error::InvalidSpec(format!("corpus size n={n} must be ≥ 2 to plan")));
        }
        if !(delta > 0.0 && delta < 1.0) {
            return Err(Error::InvalidSpec(format!("delta={delta} must lie in (0, 1)")));
        }
        let (p1, p2) = match self.family.metric {
            Metric::Euclidean => {
                if !(r1 > 0.0 && r1.is_finite()) {
                    return Err(Error::InvalidSpec(format!("near radius r1={r1} must be > 0")));
                }
                if !(c > 1.0 && c.is_finite()) {
                    return Err(Error::InvalidSpec(format!(
                        "approximation factor c={c} must be > 1"
                    )));
                }
                (
                    stats::e2lsh_collision_prob(r1, self.family.w),
                    stats::e2lsh_collision_prob(c * r1, self.family.w),
                )
            }
            Metric::Cosine => {
                if !(-1.0 < c && c < r1 && r1 <= 1.0) {
                    return Err(Error::InvalidSpec(format!(
                        "cosine planning takes near similarity r1 and far similarity c \
                         with -1 < c < r1 ≤ 1 (got r1={r1}, c={c})"
                    )));
                }
                (stats::srp_collision_prob(r1), stats::srp_collision_prob(c))
            }
        };
        if !(p1 > p2 && p2 > 0.0 && p1 < 1.0) {
            return Err(Error::InvalidSpec(format!(
                "collision probabilities p1={p1:.4}, p2={p2:.4} do not satisfy 1 > p1 > p2 > 0"
            )));
        }
        Ok(plan_parameters(n, p1, p2, delta))
    }

    /// The planned version of this spec: K and L replaced by the planner's
    /// choice, after [`validity_report`] confirms the dims/rank combination
    /// sits inside the family's asymptotic validity regime (Theorems
    /// 4/6/8/10). Rejections are typed [`Error::InvalidSpec`]s.
    pub fn planned(mut self, n: usize, r1: f64, c: f64, delta: f64) -> Result<LshSpec> {
        let rep = validity_report(&self.family.dims, self.family.rank);
        match self.family.kind {
            FamilyKind::Cp if !rep.cp_ok => {
                return Err(Error::InvalidSpec(format!(
                    "CP validity ratio {:.3} ≥ 1 at dims {:?}, rank {}: the CLT of \
                     Theorems 4/8 is not trustworthy at this shape (grow D or shrink R)",
                    rep.cp_ratio, self.family.dims, self.family.rank
                )));
            }
            FamilyKind::Tt if !rep.tt_ok => {
                return Err(Error::InvalidSpec(format!(
                    "TT validity ratio {:.3} ≥ 1 at dims {:?}, rank {}: the CLT of \
                     Theorems 6/10 is not trustworthy at this shape (grow D or shrink R)",
                    rep.tt_ratio, self.family.dims, self.family.rank
                )));
            }
            _ => {}
        }
        let plan = self.plan(n, r1, c, delta)?;
        self.family.k = plan.k;
        self.l = plan.l;
        self.validate()?;
        Ok(self)
    }

    // -- family / bank construction ----------------------------------------

    /// Build table `t`'s hash family. Replaces the hand-rolled
    /// `family_builder` closures: per-table seeds come from the
    /// [`SeedPolicy`] (or, when [`LshSpec::banded`], table `t` carries band
    /// `t` of the one full-width bank).
    ///
    /// Panics on an invalid spec — the `from_spec` constructors validate
    /// first; call [`LshSpec::try_family`] to keep the typed error.
    pub fn family(&self, table: usize) -> Arc<dyn HashFamily> {
        self.try_family(table)
            .expect("invalid LshSpec — validate() before family()")
    }

    /// [`LshSpec::family`], returning validation failures as typed errors.
    pub fn try_family(&self, table: usize) -> Result<Arc<dyn HashFamily>> {
        self.validate()?;
        if table >= self.l {
            return Err(Error::InvalidSpec(format!(
                "table {table} out of range (l = {})",
                self.l
            )));
        }
        if self.banded {
            self.banded_family(table)
        } else {
            self.family.build(self.seeds.table_seed(table))
        }
    }

    /// All L table families at once. For banded specs this generates the
    /// full bank **once** and slices every band off it (unlike L separate
    /// [`LshSpec::try_family`] calls, which regenerate the bank per table) —
    /// the `from_spec` index constructors route through here.
    pub fn families(&self) -> Result<Vec<Arc<dyn HashFamily>>> {
        self.validate()?;
        if !self.banded {
            return (0..self.l).map(|t| self.try_family(t)).collect();
        }
        let (k, w, base) = (self.family.k, self.family.w, self.seeds.base);
        let p = self.family.precision;
        Ok(match (self.family.kind, self.family.metric) {
            (FamilyKind::Cp, Metric::Cosine) => {
                let bank = self.cp_bank()?;
                (0..self.l)
                    .map(|t| {
                        Arc::new(SrpHasher::wrap(bank.band(t, k), "cp").with_precision(p))
                            as Arc<dyn HashFamily>
                    })
                    .collect()
            }
            (FamilyKind::Tt, Metric::Cosine) => {
                let bank = self.tt_bank()?;
                (0..self.l)
                    .map(|t| {
                        Arc::new(SrpHasher::wrap(bank.band(t, k), "tt").with_precision(p))
                            as Arc<dyn HashFamily>
                    })
                    .collect()
            }
            (FamilyKind::Sparse, Metric::Cosine) => {
                let bank = self.sparse_bank()?;
                (0..self.l)
                    .map(|t| {
                        Arc::new(SrpHasher::wrap(bank.band(t, k), "sparse").with_precision(p))
                            as Arc<dyn HashFamily>
                    })
                    .collect()
            }
            (FamilyKind::Cp, Metric::Euclidean) => {
                let full = E2lshHasher::wrap(self.cp_bank()?, w, base, "cp");
                (0..self.l)
                    .map(|t| {
                        let b = full.b[t * k..(t + 1) * k].to_vec();
                        Arc::new(
                            E2lshHasher::with_offsets(full.proj.band(t, k), b, w, "cp")
                                .with_precision(p),
                        ) as Arc<dyn HashFamily>
                    })
                    .collect()
            }
            (FamilyKind::Tt, Metric::Euclidean) => {
                let full = E2lshHasher::wrap(self.tt_bank()?, w, base, "tt");
                (0..self.l)
                    .map(|t| {
                        let b = full.b[t * k..(t + 1) * k].to_vec();
                        Arc::new(
                            E2lshHasher::with_offsets(full.proj.band(t, k), b, w, "tt")
                                .with_precision(p),
                        ) as Arc<dyn HashFamily>
                    })
                    .collect()
            }
            (FamilyKind::Sparse, Metric::Euclidean) => {
                let full = E2lshHasher::wrap(self.sparse_bank()?, w, base, "sparse");
                (0..self.l)
                    .map(|t| {
                        let b = full.b[t * k..(t + 1) * k].to_vec();
                        Arc::new(
                            E2lshHasher::with_offsets(full.proj.band(t, k), b, w, "sparse")
                                .with_precision(p),
                        ) as Arc<dyn HashFamily>
                    })
                    .collect()
            }
            (FamilyKind::Naive, _) => unreachable!("validate() rejects banded naive"),
        })
    }

    /// The full `K·L`-wide CP projection bank a banded spec slices — the
    /// same bank the PJRT serving path hands to the artifact executor.
    pub fn cp_bank(&self) -> Result<CpRademacher> {
        if self.family.kind != FamilyKind::Cp {
            return Err(Error::InvalidSpec(format!(
                "cp_bank on a {} spec",
                self.family.kind.name()
            )));
        }
        self.family.validate()?;
        Ok(self.family.cp_proj(self.seeds.base, self.family.k * self.l))
    }

    /// TT analogue of [`LshSpec::cp_bank`].
    pub fn tt_bank(&self) -> Result<TtRademacher> {
        if self.family.kind != FamilyKind::Tt {
            return Err(Error::InvalidSpec(format!(
                "tt_bank on a {} spec",
                self.family.kind.name()
            )));
        }
        self.family.validate()?;
        Ok(self.family.tt_proj(self.seeds.base, self.family.k * self.l))
    }

    /// Sparse analogue of [`LshSpec::cp_bank`]: `K·L` sampled-coordinate
    /// hashes drawn at the base seed, band-sliced per table.
    pub fn sparse_bank(&self) -> Result<SparseGaussian> {
        if self.family.kind != FamilyKind::Sparse {
            return Err(Error::InvalidSpec(format!(
                "sparse_bank on a {} spec",
                self.family.kind.name()
            )));
        }
        self.family.validate()?;
        Ok(self.family.sparse_proj(self.seeds.base, self.family.k * self.l))
    }

    /// Band `t` of the full bank, wrapped in the metric's discretizer. The
    /// E2LSH offsets are the matching slice of the full-width hasher's, so
    /// banded tables discretize exactly like code slices of the full bank.
    fn banded_family(&self, table: usize) -> Result<Arc<dyn HashFamily>> {
        let k = self.family.k;
        let w = self.family.w;
        let p = self.family.precision;
        Ok(match (self.family.kind, self.family.metric) {
            (FamilyKind::Cp, Metric::Cosine) => Arc::new(
                SrpHasher::wrap(self.cp_bank()?.band(table, k), "cp").with_precision(p),
            ),
            (FamilyKind::Tt, Metric::Cosine) => Arc::new(
                SrpHasher::wrap(self.tt_bank()?.band(table, k), "tt").with_precision(p),
            ),
            (FamilyKind::Sparse, Metric::Cosine) => Arc::new(
                SrpHasher::wrap(self.sparse_bank()?.band(table, k), "sparse").with_precision(p),
            ),
            (FamilyKind::Cp, Metric::Euclidean) => {
                let bank = self.cp_bank()?;
                let band = bank.band(table, k);
                let full = E2lshHasher::wrap(bank, w, self.seeds.base, "cp");
                let b = full.b[table * k..(table + 1) * k].to_vec();
                Arc::new(E2lshHasher::with_offsets(band, b, w, "cp").with_precision(p))
            }
            (FamilyKind::Tt, Metric::Euclidean) => {
                let bank = self.tt_bank()?;
                let band = bank.band(table, k);
                let full = E2lshHasher::wrap(bank, w, self.seeds.base, "tt");
                let b = full.b[table * k..(table + 1) * k].to_vec();
                Arc::new(E2lshHasher::with_offsets(band, b, w, "tt").with_precision(p))
            }
            (FamilyKind::Sparse, Metric::Euclidean) => {
                let bank = self.sparse_bank()?;
                let band = bank.band(table, k);
                let full = E2lshHasher::wrap(bank, w, self.seeds.base, "sparse");
                let b = full.b[table * k..(table + 1) * k].to_vec();
                Arc::new(E2lshHasher::with_offsets(band, b, w, "sparse").with_precision(p))
            }
            (FamilyKind::Naive, _) => unreachable!("validate() rejects banded naive"),
        })
    }

    /// The deprecated closure-based [`IndexConfig`], built *from* this spec
    /// (escape hatch for code still on the legacy constructor surface).
    pub fn index_config(&self) -> Result<IndexConfig> {
        IndexConfig::from_spec(self)
    }

    // -- JSON --------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("family".to_string(), self.family.to_json());
        m.insert("l".to_string(), Json::Num(self.l as f64));
        m.insert("probes".to_string(), Json::Num(self.probes as f64));
        m.insert("banded".to_string(), Json::Bool(self.banded));
        m.insert("seeds".to_string(), self.seeds.to_json());
        m.insert("serving".to_string(), self.serving.to_json());
        Json::Obj(m)
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Parse and validate a spec. `banded`, `seeds`, and `serving` may be
    /// omitted in hand-written files (defaults apply), but unknown keys are
    /// rejected — a typo must not silently become a default.
    /// [`LshSpec::to_json`] always writes every section, so print → parse
    /// is the identity.
    pub fn from_json(v: &Json) -> Result<LshSpec> {
        reject_unknown(v, &["family", "l", "probes", "banded", "seeds", "serving"], "spec")?;
        let obj = v.as_obj()?;
        let spec = LshSpec {
            family: FamilySpec::from_json(v.get("family")?)?,
            l: v.get("l")?.as_usize()?,
            probes: match obj.get("probes") {
                Some(p) => p.as_usize()?,
                None => 0,
            },
            banded: match obj.get("banded") {
                Some(Json::Bool(b)) => *b,
                Some(other) => {
                    return Err(Error::Json(format!("expected bool for 'banded', got {other:?}")))
                }
                None => false,
            },
            seeds: match obj.get("seeds") {
                Some(s) => SeedPolicy::from_json(s)?,
                None => SeedPolicy::default(),
            },
            serving: match obj.get("serving") {
                Some(s) => ServingSpec::from_json(s)?,
                None => ServingSpec::default(),
            },
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn from_json_str(text: &str) -> Result<LshSpec> {
        LshSpec::from_json(&parse(text)?)
    }
}

/// Largest integer a JSON (f64) number represents exactly: 2^53.
const MAX_JSON_INT: u64 = 1 << 53;

/// Reject unknown keys in a spec JSON object — a misspelled key must fail
/// parsing, not silently fall back to a default.
fn reject_unknown(v: &Json, allowed: &[&str], what: &str) -> Result<()> {
    for key in v.as_obj()?.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(Error::InvalidSpec(format!(
                "unknown {what} key '{key}' (expected one of: {})",
                allowed.join(", ")
            )));
        }
    }
    Ok(())
}

/// Parse a non-negative integer that must fit u64 exactly.
fn as_u64(v: &Json) -> Result<u64> {
    let f = v.as_f64()?;
    if f < 0.0 || f.fract() != 0.0 || f >= MAX_JSON_INT as f64 {
        return Err(Error::Json(format!("expected non-negative integer (< 2^53), got {f}")));
    }
    // Checked conversion: the guard above proves f is a non-negative
    // integer below 2^53, so the cast is exact.
    #[allow(clippy::cast_possible_truncation)]
    Ok(f as u64)
}

// ---------------------------------------------------------------------------
// Fluent builders
// ---------------------------------------------------------------------------

/// Fluent construction of [`LshIndex`] / [`ShardedLshIndex`] from an
/// [`LshSpec`].
///
/// ```
/// use tensor_lsh::prelude::*;
///
/// let index = IndexBuilder::new(LshSpec::cosine(FamilyKind::Tt, vec![6, 6, 6], 3, 8, 4))
///     .probes(2)
///     .seed(9, 1)
///     .build()?;
/// assert_eq!(index.n_tables(), 4);
/// # Ok::<(), tensor_lsh::Error>(())
/// ```
#[derive(Clone, Debug)]
pub struct IndexBuilder {
    spec: LshSpec,
}

impl IndexBuilder {
    pub fn new(spec: LshSpec) -> IndexBuilder {
        IndexBuilder { spec }
    }

    /// Number of tables L.
    pub fn tables(mut self, l: usize) -> IndexBuilder {
        self.spec.l = l;
        self
    }

    /// Multiprobe extras per table.
    pub fn probes(mut self, probes: usize) -> IndexBuilder {
        self.spec.probes = probes;
        self
    }

    /// Seed policy: table `t` seeds at `base + stride·t`.
    pub fn seed(mut self, base: u64, stride: u64) -> IndexBuilder {
        self.spec.seeds = SeedPolicy::new(base, stride);
        self
    }

    /// Shard count for the sharded builds.
    pub fn shards(mut self, shards: usize) -> IndexBuilder {
        self.spec.serving.shards = shards;
        self
    }

    /// Replace K and L with the planner's choice (see [`LshSpec::planned`]).
    pub fn planned(mut self, n: usize, r1: f64, c: f64, delta: f64) -> Result<IndexBuilder> {
        self.spec = self.spec.planned(n, r1, c, delta)?;
        Ok(self)
    }

    pub fn spec(&self) -> &LshSpec {
        &self.spec
    }

    pub fn into_spec(self) -> LshSpec {
        self.spec
    }

    /// Empty single-shard index.
    pub fn build(self) -> Result<LshIndex> {
        LshIndex::from_spec(&self.spec)
    }

    /// Bulk-built single-shard index (batched hashing).
    pub fn build_with(self, items: Vec<AnyTensor>) -> Result<LshIndex> {
        LshIndex::build_from_spec(&self.spec, items)
    }

    /// Empty sharded serving index (`spec.serving.shards` shards).
    pub fn build_sharded(self) -> Result<ShardedLshIndex> {
        ShardedLshIndex::from_spec(&self.spec)
    }

    /// Bulk-built sharded index (one build thread per shard).
    pub fn build_sharded_with(self, items: Vec<AnyTensor>) -> Result<ShardedLshIndex> {
        ShardedLshIndex::build_from_spec(&self.spec, items)
    }
}

/// Fluent construction of the serving pipeline from an [`LshSpec`]: the
/// same spec that hashed the corpus configures the coordinator.
///
/// ```no_run
/// use std::sync::Arc;
/// use tensor_lsh::prelude::*;
///
/// # fn items() -> Vec<AnyTensor> { Vec::new() }
/// let spec = LshSpec::cosine(FamilyKind::Cp, vec![8, 8, 8], 4, 10, 6);
/// let serving = CoordinatorBuilder::new(spec).workers(4).max_batch(32);
/// let index = serving.build_index(items())?;
/// let _coordinator = serving.start(Arc::clone(&index));
/// # Ok::<(), tensor_lsh::Error>(())
/// ```
#[derive(Clone, Debug)]
pub struct CoordinatorBuilder {
    spec: LshSpec,
}

impl CoordinatorBuilder {
    pub fn new(spec: LshSpec) -> CoordinatorBuilder {
        CoordinatorBuilder { spec }
    }

    pub fn workers(mut self, n: usize) -> CoordinatorBuilder {
        self.spec.serving.n_workers = n;
        self
    }

    pub fn shards(mut self, shards: usize) -> CoordinatorBuilder {
        self.spec.serving.shards = shards;
        self
    }

    pub fn max_batch(mut self, max_batch: usize) -> CoordinatorBuilder {
        self.spec.serving.max_batch = max_batch;
        self
    }

    pub fn max_wait_us(mut self, us: u64) -> CoordinatorBuilder {
        self.spec.serving.max_wait_us = us;
        self
    }

    pub fn spec(&self) -> &LshSpec {
        &self.spec
    }

    /// The coordinator policy view of the spec.
    pub fn config(&self) -> CoordinatorConfig {
        CoordinatorConfig::from_spec(&self.spec)
    }

    /// Attach a durable store to the serving config (see [`StoreSpec`]).
    pub fn store(mut self, store: StoreSpec) -> CoordinatorBuilder {
        self.spec.serving.store = Some(store);
        self
    }

    /// Hash + insert a corpus into a fresh sharded index per the spec.
    pub fn build_index(&self, items: Vec<AnyTensor>) -> Result<Arc<ShardedLshIndex>> {
        Ok(Arc::new(ShardedLshIndex::build_from_spec(&self.spec, items)?))
    }

    /// Spin up the pipeline over a built index (native hash backend).
    pub fn start(&self, index: Arc<ShardedLshIndex>) -> Coordinator {
        Coordinator::start(index, self.config(), HashBackend::Native)
    }

    /// Initialize the spec's durable store from a corpus: build the sharded
    /// index, write snapshot generation 1, open the WAL. Requires
    /// `spec.serving.store` (typed error otherwise).
    pub fn create_store(&self, items: Vec<AnyTensor>) -> Result<Arc<Store>> {
        let store_spec = self.store_spec()?;
        let index = self.build_index(items)?;
        Ok(Arc::new(
            Store::create(store_spec.dir.as_ref(), index, store_spec.checkpoint_every)?
                .with_compact_dead_fraction(store_spec.compact_dead_fraction),
        ))
    }

    /// Warm-start from the spec's durable store: newest valid snapshot +
    /// WAL replay ([`Store::open_with`]), honouring the spec's per-shard
    /// [`Residency`] policy (paged shards serve buckets/items on demand).
    pub fn open_store(&self) -> Result<Arc<Store>> {
        let store_spec = self.store_spec()?;
        Ok(Arc::new(
            Store::open_with(
                store_spec.dir.as_ref(),
                store_spec.checkpoint_every,
                store_spec.residency,
            )?
            .with_compact_dead_fraction(store_spec.compact_dead_fraction),
        ))
    }

    /// Spin up the pipeline over a durable store (native hash backend):
    /// queries serve from [`Store::index`], [`Coordinator::insert`] routes
    /// through the WAL, and shutdown checkpoints pending inserts.
    pub fn start_durable(&self, store: Arc<Store>) -> Coordinator {
        Coordinator::start_durable(store, self.config(), HashBackend::Native)
    }

    fn store_spec(&self) -> Result<&StoreSpec> {
        self.spec.serving.store.as_ref().ok_or_else(|| {
            Error::InvalidSpec(
                "spec.serving.store is not configured (use CoordinatorBuilder::store \
                 or LshSpec::with_store)"
                    .into(),
            )
        })
    }

    /// Push a whole query trace through a fresh coordinator and collect the
    /// responses plus final metrics (native hash backend).
    pub fn serve_trace(
        &self,
        index: Arc<ShardedLshIndex>,
        queries: Vec<QueryRequest>,
    ) -> Result<(Vec<QueryResponse>, MetricsSnapshot)> {
        Coordinator::serve_trace(index, self.config(), HashBackend::Native, queries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::CodeMatrix;
    use crate::rng::Rng;
    use crate::tensor::CpTensor;

    fn batch(dims: &[usize], n: usize, seed: u64) -> Vec<AnyTensor> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| AnyTensor::Cp(CpTensor::random_gaussian(&mut rng, dims, 2)))
            .collect()
    }

    #[test]
    fn json_roundtrip_is_identity() {
        let spec = LshSpec::euclidean(FamilyKind::Tt, vec![6, 7, 8], 3, 9, 5, 2.5)
            .with_probes(4)
            .with_seed(123456789, 17)
            .with_serving(ServingSpec {
                shards: 3,
                n_workers: 2,
                max_batch: 16,
                max_wait_us: 250,
                ..Default::default()
            });
        let text = spec.to_json_string();
        let back = LshSpec::from_json_str(&text).unwrap();
        assert_eq!(back, spec);
        // And a second trip is stable.
        assert_eq!(back.to_json_string(), text);
        // The optional store section round-trips too.
        let durable = spec
            .clone()
            .with_store(StoreSpec::new("/var/lib/tensorlsh").with_checkpoint_every(5000));
        let back = LshSpec::from_json_str(&durable.to_json_string()).unwrap();
        assert_eq!(back, durable);
        assert_eq!(back.serving.store.as_ref().unwrap().checkpoint_every, 5000);
        // With the trigger disarmed (0.0) the key is omitted entirely, so
        // the JSON is identical to what pre-knob builds emitted…
        assert!(!durable.to_json_string().contains("compact_dead_fraction"));
        // …and when armed it round-trips bit-exactly.
        let churny = spec.clone().with_store(
            StoreSpec::new("/var/lib/tensorlsh").with_compact_dead_fraction(0.25),
        );
        let back = LshSpec::from_json_str(&churny.to_json_string()).unwrap();
        assert_eq!(back, churny);
        assert_eq!(
            back.serving.store.as_ref().unwrap().compact_dead_fraction,
            0.25
        );
        // Residency follows the same omit-when-default rule: Resident emits
        // no key, every other mode round-trips through its string form.
        assert!(!durable.to_json_string().contains("residency"));
        for residency in [
            crate::store::Residency::Paged { lru_cap: 512 },
            crate::store::Residency::Paged {
                lru_cap: crate::store::Residency::DEFAULT_LRU_CAP,
            },
            crate::store::Residency::Auto,
        ] {
            let paged = spec
                .clone()
                .with_store(StoreSpec::new("/var/lib/tensorlsh").with_residency(residency));
            let text = paged.to_json_string();
            assert!(text.contains("residency"), "{text}");
            let back = LshSpec::from_json_str(&text).unwrap();
            assert_eq!(back, paged);
            assert_eq!(back.serving.store.as_ref().unwrap().residency, residency);
        }
        // An unknown residency string is a typed parse error.
        let bad = parse(r#"{"dir": "d", "residency": "sometimes"}"#).unwrap();
        assert!(StoreSpec::from_json(&bad).is_err());
        // An empty store dir is a typed validation error.
        assert!(matches!(
            spec.clone().with_store(StoreSpec::new("")).validate(),
            Err(Error::InvalidSpec(_))
        ));
        // Out-of-range dead fractions are typed validation errors.
        for bad in [-0.1, 1.0, f64::NAN, f64::INFINITY] {
            assert!(
                matches!(
                    spec.clone()
                        .with_store(StoreSpec::new("d").with_compact_dead_fraction(bad))
                        .validate(),
                    Err(Error::InvalidSpec(_))
                ),
                "compact_dead_fraction {bad} must be rejected"
            );
        }
        // The optional listener section round-trips too.
        let listening = spec.clone().with_listen(NetSpec {
            addr: "0.0.0.0:7878".to_string(),
            max_conns: 16,
            read_timeout_ms: 2500,
            write_timeout_ms: 1500,
            max_inflight: 77,
        });
        let back = LshSpec::from_json_str(&listening.to_json_string()).unwrap();
        assert_eq!(back, listening);
        // A listen object carrying only the address fills the rest from
        // defaults.
        let minimal = NetSpec::from_json(
            &crate::util::json::parse(r#"{"addr": "127.0.0.1:0"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(minimal, NetSpec { addr: "127.0.0.1:0".to_string(), ..NetSpec::default() });
        // Empty addr and zero caps are typed validation errors.
        assert!(matches!(
            spec.clone().with_listen(NetSpec::new("")).validate(),
            Err(Error::InvalidSpec(_))
        ));
        assert!(matches!(
            NetSpec { max_conns: 0, ..NetSpec::default() }.validate(),
            Err(Error::InvalidSpec(_))
        ));
    }

    #[test]
    fn json_defaults_apply_to_minimal_documents() {
        let spec = LshSpec::from_json_str(
            r#"{
                "family": {"kind": "cp", "dims": [8, 8], "rank": 4, "k": 6,
                           "metric": "cosine", "w": 4.0},
                "l": 3
            }"#,
        )
        .unwrap();
        assert_eq!(spec.probes, 0);
        assert!(!spec.banded);
        assert_eq!(spec.seeds, SeedPolicy::default());
        assert_eq!(spec.serving, ServingSpec::default());
    }

    #[test]
    fn invalid_numerics_are_typed_errors() {
        let base = LshSpec::cosine(FamilyKind::Cp, vec![8, 8], 4, 6, 3);
        for bad in [
            base.clone().with_k(0),
            base.clone().with_tables(0),
            LshSpec::cosine(FamilyKind::Cp, vec![], 4, 6, 3),
            LshSpec::cosine(FamilyKind::Cp, vec![8, 0], 4, 6, 3),
            LshSpec::cosine(FamilyKind::Cp, vec![8, 8], 0, 6, 3),
            LshSpec::euclidean(FamilyKind::Cp, vec![8, 8], 4, 6, 3, 0.0),
            LshSpec::euclidean(FamilyKind::Cp, vec![8, 8], 4, 6, 3, -1.0),
            base.clone().with_seed(1, 0),
            // Seeds ≥ 2^53 would round-trip lossily through JSON numbers.
            base.clone().with_seed(u64::MAX, 1),
            base.clone().with_seed(1, 1 << 53),
            LshSpec::cosine(FamilyKind::Naive, vec![8, 8], 1, 6, 3).with_banded(true),
        ] {
            match bad.validate() {
                Err(Error::InvalidSpec(_)) => {}
                other => panic!("expected InvalidSpec, got {other:?}"),
            }
        }
        // JSON parsing validates too.
        let err = LshSpec::from_json_str(
            r#"{"family": {"kind": "cp", "dims": [8], "rank": 4, "k": 0,
                           "metric": "cosine", "w": 4.0}, "l": 3}"#,
        );
        assert!(matches!(err, Err(Error::InvalidSpec(_))));
        // Misspelled keys fail parsing instead of silently defaulting.
        let typo = LshSpec::from_json_str(
            r#"{"family": {"kind": "cp", "dims": [8], "rank": 4, "k": 6,
                           "metric": "cosine", "w": 4.0}, "l": 3, "probess": 4}"#,
        );
        assert!(matches!(typo, Err(Error::InvalidSpec(_))));
        assert!(matches!(FamilyKind::parse("foo"), Err(Error::InvalidSpec(_))));
        let msg = match FamilyKind::parse("foo") {
            Err(Error::InvalidSpec(m)) => m,
            other => panic!("{other:?}"),
        };
        assert!(
            msg.contains("cp") && msg.contains("tt") && msg.contains("naive")
                && msg.contains("sparse"),
            "{msg}"
        );
    }

    #[test]
    fn spec_families_match_direct_construction() {
        // The spec path must be bit-identical to hand-built hashers at the
        // same seeds — this is what makes the builder migration safe.
        let dims = vec![6usize, 6, 6];
        let spec = LshSpec::euclidean(FamilyKind::Cp, dims.clone(), 3, 8, 4, 4.0)
            .with_seed(70, 1000);
        let xs = batch(&dims, 5, 1);
        for t in 0..spec.l {
            let seed = 70 + 1000 * t as u64;
            let direct = E2lshHasher::wrap(
                CpRademacher::generate(seed, &dims, 3, 8, Distribution::Rademacher),
                4.0,
                seed,
                "cp",
            );
            let fam = spec.family(t);
            assert_eq!(fam.name(), "cp-e2lsh");
            for x in &xs {
                assert_eq!(fam.hash(x), direct.hash(x), "table {t}");
            }
        }
    }

    #[test]
    fn banded_families_slice_the_full_bank() {
        // A banded spec's table t must hash exactly like codes
        // [t·K, (t+1)·K) of the one full-width hasher — for SRP and E2LSH.
        let dims = vec![6usize, 6, 6];
        let xs = batch(&dims, 4, 2);
        for metric in [Metric::Cosine, Metric::Euclidean] {
            let spec = LshSpec {
                family: FamilySpec {
                    kind: FamilyKind::Cp,
                    dims: dims.clone(),
                    rank: 3,
                    k: 4,
                    metric,
                    w: 4.0,
                    precision: Precision::F64,
                    sample: 0,
                },
                l: 3,
                probes: 0,
                banded: true,
                seeds: SeedPolicy::new(99, 0),
                serving: ServingSpec::default(),
            };
            let bank = spec.cp_bank().unwrap();
            assert_eq!(crate::projection::Projection::k(&bank), 12);
            let full: Arc<dyn HashFamily> = match metric {
                Metric::Cosine => Arc::new(SrpHasher::wrap(bank, "cp")),
                Metric::Euclidean => Arc::new(E2lshHasher::wrap(bank, 4.0, 99, "cp")),
            };
            // Per-table construction and the one-bank families() path must
            // both equal slices of the full hasher's codes.
            let fams = spec.families().unwrap();
            for x in &xs {
                let full_codes = full.hash(x);
                for t in 0..3 {
                    let band_codes = full_codes[t * 4..(t + 1) * 4].to_vec();
                    assert_eq!(
                        spec.family(t).hash(x),
                        band_codes,
                        "metric {metric:?} band {t}"
                    );
                    assert_eq!(fams[t].hash(x), band_codes, "families() band {t}");
                }
            }
        }
    }

    #[test]
    fn sparse_and_precision_round_trip_json() {
        let spec = LshSpec::euclidean(FamilyKind::Sparse, vec![6, 6, 6], 1, 8, 4, 3.0)
            .with_sample(32)
            .with_precision(Precision::F32)
            .with_seed(7, 100);
        let text = spec.to_json_string();
        let back = LshSpec::from_json_str(&text).unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.family.kind, FamilyKind::Sparse);
        assert_eq!(back.family.precision, Precision::F32);
        assert_eq!(back.family.sample, 32);
        assert_eq!(back.to_json_string(), text);
        // Documents predating PR 7 omit precision/sample: they parse to the
        // historical behavior (f64 reference, auto sampling).
        let old = LshSpec::from_json_str(
            r#"{
                "family": {"kind": "cp", "dims": [8, 8], "rank": 4, "k": 6,
                           "metric": "cosine", "w": 4.0},
                "l": 3
            }"#,
        )
        .unwrap();
        assert_eq!(old.family.precision, Precision::F64);
        assert_eq!(old.family.sample, 0);
        // A bad precision value is a typed error, not a silent default.
        let bad = LshSpec::from_json_str(
            r#"{
                "family": {"kind": "cp", "dims": [8, 8], "rank": 4, "k": 6,
                           "metric": "cosine", "w": 4.0, "precision": "f16"},
                "l": 3
            }"#,
        );
        assert!(bad.is_err());
        // "fast" is an accepted alias for the sparse kind.
        assert_eq!(FamilyKind::parse("fast").unwrap(), FamilyKind::Sparse);
    }

    #[test]
    fn sparse_spec_builds_all_layers() {
        let dims = vec![6usize, 6, 6];
        let xs = batch(&dims, 4, 9);
        // Per-table families hash deterministically under both metrics.
        let srp = LshSpec::cosine(FamilyKind::Sparse, dims.clone(), 1, 6, 3).with_sample(40);
        let e2 = LshSpec::euclidean(FamilyKind::Sparse, dims.clone(), 1, 6, 3, 4.0)
            .with_sample(40);
        for spec in [&srp, &e2] {
            let fams = spec.families().unwrap();
            assert_eq!(fams.len(), 3);
            for t in 0..3 {
                let again = spec.family(t);
                for x in &xs {
                    assert_eq!(fams[t].hash(x), again.hash(x), "table {t}");
                }
            }
        }
        assert_eq!(srp.family(0).name(), "sparse-srp");
        assert_eq!(e2.family(0).name(), "sparse-e2lsh");
        // The auto sample default is D/4.
        assert_eq!(srp.family.clone().with_sample(0).sparse_m(), 54);
        // Planner accepts the sparse kind (collision laws depend only on the
        // metric and w; the validity gate is CP/TT-specific).
        let planned = LshSpec::cosine(FamilyKind::Sparse, vec![16, 16], 1, 1, 1)
            .planned(10_000, 0.9, 0.3, 0.5)
            .unwrap();
        assert!(planned.family.k >= 1 && planned.l >= 1);
        // End-to-end: a sparse spec drives the index builder.
        let items = batch(&dims, 30, 11);
        let index = IndexBuilder::new(srp.clone()).build_with(items.clone()).unwrap();
        assert_eq!(index.len(), 30);
    }

    #[test]
    fn banded_sparse_slices_the_full_bank() {
        // Banded sparse table t must hash exactly like codes [t·K, (t+1)·K)
        // of the one full-width sparse hasher — mirroring the CP/TT banding
        // contract.
        let dims = vec![6usize, 6, 6];
        let xs = batch(&dims, 4, 3);
        for metric in [Metric::Cosine, Metric::Euclidean] {
            let mut spec = LshSpec::cosine(FamilyKind::Sparse, dims.clone(), 1, 4, 3)
                .with_sample(30)
                .with_banded(true)
                .with_seed(99, 0);
            spec.family.metric = metric;
            let bank = spec.sparse_bank().unwrap();
            assert_eq!(crate::projection::Projection::k(&bank), 12);
            let full: Arc<dyn HashFamily> = match metric {
                Metric::Cosine => Arc::new(SrpHasher::wrap(bank, "sparse")),
                Metric::Euclidean => Arc::new(E2lshHasher::wrap(bank, 4.0, 99, "sparse")),
            };
            let fams = spec.families().unwrap();
            for x in &xs {
                let full_codes = full.hash(x);
                for t in 0..3 {
                    let band_codes = full_codes[t * 4..(t + 1) * 4].to_vec();
                    assert_eq!(spec.family(t).hash(x), band_codes, "{metric:?} band {t}");
                    assert_eq!(fams[t].hash(x), band_codes, "families() band {t}");
                }
            }
        }
    }

    #[test]
    fn precision_propagates_from_spec_to_families() {
        let dims = vec![6usize, 6, 6];
        let f64_spec = LshSpec::euclidean(FamilyKind::Cp, dims.clone(), 3, 6, 2, 4.0);
        let f32_spec = f64_spec.clone().with_precision(Precision::F32);
        assert_eq!(f64_spec.family(0).precision(), Precision::F64);
        assert_eq!(f32_spec.family(0).precision(), Precision::F32);
        for f in f32_spec.families().unwrap() {
            assert_eq!(f.precision(), Precision::F32);
        }
        // Banded families carry the precision too.
        let banded = f32_spec.clone().with_banded(true).with_seed(5, 0);
        for f in banded.families().unwrap() {
            assert_eq!(f.precision(), Precision::F32);
        }
        // f32 codes may drift only at bucket boundaries: spot-check that the
        // two precisions agree on the vast majority of codes.
        let xs = batch(&dims, 16, 21);
        let (mut same, mut total) = (0usize, 0usize);
        let (a, b) = (f64_spec.family(0), f32_spec.family(0));
        for x in &xs {
            for (ca, cb) in a.hash(x).iter().zip(b.hash(x)) {
                same += usize::from(*ca == cb);
                total += 1;
            }
        }
        assert!(
            same * 100 >= total * 95,
            "f32/f64 agreement {same}/{total} below 95%"
        );
    }

    #[test]
    fn planned_sets_k_l_from_theory_and_gates_validity() {
        // Valid regime: big D, small R.
        let spec = LshSpec::cosine(FamilyKind::Cp, vec![64, 64, 64, 64], 2, 1, 1)
            .planned(10_000, 0.9, 0.3, 0.5)
            .unwrap();
        assert!(spec.family.k > 1 && spec.l >= 1);
        let plan = spec.plan(10_000, 0.9, 0.3, 0.5).unwrap();
        assert_eq!((plan.k, plan.l), (spec.family.k, spec.l));
        assert!(plan.recall_bound >= 0.5 - 1e-9);

        // Outside the regime: typed rejection, not a bad index.
        let bad = LshSpec::cosine(FamilyKind::Cp, vec![4, 4, 4], 4096, 8, 4)
            .planned(10_000, 0.9, 0.3, 0.5);
        assert!(matches!(bad, Err(Error::InvalidSpec(_))));

        // Degenerate thresholds are typed errors, not planner panics.
        let degenerate = LshSpec::cosine(FamilyKind::Cp, vec![64, 64, 64, 64], 2, 1, 1)
            .plan(10_000, 0.3, 0.9, 0.5);
        assert!(matches!(degenerate, Err(Error::InvalidSpec(_))));
        let bad_c = LshSpec::euclidean(FamilyKind::Cp, vec![64, 64, 64, 64], 2, 1, 1, 4.0)
            .plan(10_000, 1.0, 0.5, 0.5);
        assert!(matches!(bad_c, Err(Error::InvalidSpec(_))));
    }

    #[test]
    fn index_builder_builds_both_structures_identically() {
        let dims = vec![8usize, 8, 8];
        let items = batch(&dims, 60, 3);
        let spec = LshSpec::cosine(FamilyKind::Cp, dims, 4, 10, 6).with_seed(1000, 1);
        let single = IndexBuilder::new(spec.clone()).build_with(items.clone()).unwrap();
        let sharded = IndexBuilder::new(spec.clone())
            .shards(3)
            .build_sharded_with(items.clone())
            .unwrap();
        assert_eq!(single.len(), sharded.len());
        let opts = crate::query::QueryOpts::top_k(5);
        for q in items.iter().take(8) {
            assert_eq!(
                single.query_with(q, &opts).unwrap().hits,
                sharded.query_with(q, &opts).unwrap().hits
            );
        }
        // Codes off the spec's family list equal the index's own families.
        let cm_spec = CodeMatrix::build(&spec.families().unwrap(), &items[..8]);
        let cm_index = CodeMatrix::build(single.families(), &items[..8]);
        for b in 0..8 {
            assert_eq!(cm_spec.sigs_row(b), cm_index.sigs_row(b));
        }
    }

    #[test]
    fn try_family_rejects_out_of_range_table() {
        let spec = LshSpec::cosine(FamilyKind::Cp, vec![8, 8], 2, 4, 2);
        assert!(spec.try_family(1).is_ok());
        assert!(matches!(spec.try_family(2), Err(Error::InvalidSpec(_))));
    }
}
