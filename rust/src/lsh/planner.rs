//! LSH parameter planning and the paper's validity conditions.
//!
//! * [`plan_parameters`] — classical (K, L) selection from the `(R₁, R₂, P₁,
//!   P₂)`-sensitivity of Definition 1: `ρ = ln(1/P₁)/ln(1/P₂)`,
//!   `L = ⌈n^ρ⌉` for success probability `1 − δ`.
//! * [`cp_condition_ratio`] / [`tt_condition_ratio`] — the asymptotic
//!   validity conditions of Theorems 3–10:
//!   CP: `√R·N^{4/5} = o(D^{(3N−8)/(10N)})`,
//!   TT: `√(R^{N−1})·N^{4/5} = o(D^{(3N−8)/(10N)})`, `D = Π dₙ`.
//!   The *ratio* (LHS/RHS) is the practitioners' diagnostic: ≪ 1 means the
//!   CLT is trustworthy at this shape; F4 sweeps it.

// Not the precision-audited hash path: planner rounds small positive ceil() results.
#![allow(clippy::cast_possible_truncation)]

use crate::stats;

/// Outcome of (K, L) planning.
#[derive(Clone, Debug)]
pub struct LshPlan {
    /// Hashes per table signature.
    pub k: usize,
    /// Number of tables.
    pub l: usize,
    /// Sensitivity exponent ρ = ln(1/p1)/ln(1/p2).
    pub rho: f64,
    /// Single-hash collision probabilities at the near/far thresholds.
    pub p1: f64,
    pub p2: f64,
    /// Probability a near neighbor is found in ≥1 table.
    pub recall_bound: f64,
}

/// Plan (K, L) for an E2LSH-style family with bucket width `w`, near radius
/// `r1`, far radius `r2 = c·r1`, corpus size `n`, failure budget `delta`.
pub fn plan_parameters(
    n: usize,
    p1: f64,
    p2: f64,
    delta: f64,
) -> LshPlan {
    assert!(p1 > p2 && p2 > 0.0 && p1 < 1.0, "need 1 > p1 > p2 > 0");
    let rho = (1.0 / p1).ln() / (1.0 / p2).ln();
    // K chosen so that far points collide on a full signature with prob ~1/n.
    let k = ((n as f64).ln() / (1.0 / p2).ln()).ceil().max(1.0) as usize;
    // Per-table near-neighbor full-signature collision prob.
    let p1k = p1.powi(k as i32);
    // L tables so that miss probability (1 - p1^K)^L <= delta.
    let l = if p1k >= 1.0 {
        1
    } else {
        (delta.ln() / (1.0 - p1k).ln()).ceil().max(1.0) as usize
    };
    let recall_bound = 1.0 - (1.0 - p1k).powi(l as i32);
    LshPlan { k, l, rho, p1, p2, recall_bound }
}

/// Plan parameters for Euclidean search: near radius `r1`, approximation
/// factor `c` (far = c·r1), bucket width `w`.
pub fn plan_euclidean(n: usize, r1: f64, c: f64, w: f64, delta: f64) -> LshPlan {
    let p1 = stats::e2lsh_collision_prob(r1, w);
    let p2 = stats::e2lsh_collision_prob(c * r1, w);
    plan_parameters(n, p1, p2, delta)
}

/// Plan parameters for cosine search: near similarity `s1`, far `s2`.
pub fn plan_cosine(n: usize, s1: f64, s2: f64, delta: f64) -> LshPlan {
    let p1 = stats::srp_collision_prob(s1);
    let p2 = stats::srp_collision_prob(s2);
    plan_parameters(n, p1, p2, delta)
}

/// Validity diagnostic for the CP families (Theorems 3/4/7/8):
/// returns `√R·N^{4/5} / D^{(3N−8)/(10N)}` with `D = Π dims`.
pub fn cp_condition_ratio(dims: &[usize], rank: usize) -> f64 {
    let n = dims.len() as f64;
    let d: f64 = dims.iter().map(|&x| x as f64).product();
    let exponent = (3.0 * n - 8.0) / (10.0 * n);
    (rank as f64).sqrt() * n.powf(0.8) / d.powf(exponent)
}

/// Validity diagnostic for the TT families (Theorems 5/6/9/10):
/// returns `√(R^{N−1})·N^{4/5} / D^{(3N−8)/(10N)}`.
pub fn tt_condition_ratio(dims: &[usize], rank: usize) -> f64 {
    let n = dims.len() as f64;
    let d: f64 = dims.iter().map(|&x| x as f64).product();
    let exponent = (3.0 * n - 8.0) / (10.0 * n);
    (rank as f64).powf((n - 1.0) / 2.0) * n.powf(0.8) / d.powf(exponent)
}

/// Structured report on whether a configuration sits inside the theorems'
/// asymptotic validity regime.
#[derive(Clone, Debug)]
pub struct ValidityReport {
    pub cp_ratio: f64,
    pub tt_ratio: f64,
    /// Heuristic verdicts (ratio < 1 — the o(·) is asymptotic; this is the
    /// practitioner's finite-shape proxy, calibrated by experiment F4).
    pub cp_ok: bool,
    pub tt_ok: bool,
}

/// Evaluate both conditions at a shape/rank.
pub fn validity_report(dims: &[usize], rank: usize) -> ValidityReport {
    let cp_ratio = cp_condition_ratio(dims, rank);
    let tt_ratio = tt_condition_ratio(dims, rank);
    ValidityReport { cp_ratio, tt_ratio, cp_ok: cp_ratio < 1.0, tt_ok: tt_ratio < 1.0 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_shapes() {
        let plan = plan_euclidean(10_000, 1.0, 2.0, 4.0, 0.05);
        assert!(plan.k >= 1 && plan.l >= 1);
        assert!(plan.p1 > plan.p2);
        assert!(plan.rho > 0.0 && plan.rho < 1.0);
        assert!(plan.recall_bound >= 0.95 - 1e-9);
    }

    #[test]
    fn plan_cosine_sane() {
        let plan = plan_cosine(100_000, 0.9, 0.5, 0.1);
        assert!(plan.recall_bound >= 0.9 - 1e-9);
        assert!(plan.l < 10_000, "L exploded: {}", plan.l);
    }

    #[test]
    fn bigger_corpus_needs_more_tables() {
        let a = plan_cosine(1_000, 0.9, 0.3, 0.05);
        let b = plan_cosine(1_000_000, 0.9, 0.3, 0.05);
        assert!(b.k >= a.k);
    }

    #[test]
    #[should_panic(expected = "need 1 > p1 > p2 > 0")]
    fn plan_rejects_bad_probs() {
        plan_parameters(10, 0.2, 0.9, 0.1);
    }

    #[test]
    fn condition_ratios_move_the_right_way() {
        // Growing d (more elements) shrinks both ratios…
        assert!(cp_condition_ratio(&[32, 32, 32], 8) < cp_condition_ratio(&[8, 8, 8], 8));
        // …growing R grows them…
        assert!(cp_condition_ratio(&[16, 16, 16], 32) > cp_condition_ratio(&[16, 16, 16], 2));
        // …and TT's dependence on R is much steeper than CP's (√R^{N−1} vs √R):
        // at N=4, growing R 4→64 multiplies the TT ratio by 16^1.5 = 64 but
        // the CP ratio only by 4.
        let cp_growth = cp_condition_ratio(&[8, 8, 8, 8], 64) / cp_condition_ratio(&[8, 8, 8, 8], 4);
        let tt_growth = tt_condition_ratio(&[8, 8, 8, 8], 64) / tt_condition_ratio(&[8, 8, 8, 8], 4);
        assert!(tt_growth > cp_growth * 4.0);
    }

    #[test]
    fn validity_report_flags_extremes() {
        let ok = validity_report(&[64, 64, 64, 64], 2);
        assert!(ok.cp_ok);
        let bad = validity_report(&[4, 4, 4], 4096);
        assert!(!bad.cp_ok && !bad.tt_ok);
    }
}
