//! The six LSH families of the paper, behind common traits.
//!
//! Euclidean distance (E2LSH discretizer, Eq. 3.3): [`CpE2lsh`]
//! (Definition 10), [`TtE2lsh`] (Definition 11), [`NaiveE2lsh`] (reshape +
//! Datar et al. [11]).
//!
//! Cosine similarity (sign discretizer, Eq. 3.1): [`CpSrp`] (Definition 12),
//! [`TtSrp`] (Definition 13), [`NaiveSrp`] (reshape + Charikar [6]).
//!
//! Every family is a bank of K hash functions; [`HashFamily::hash`] returns
//! the K-vector of codes that the index packs into a bucket signature.
//!
//! Construction is declarative: one [`spec::FamilySpec`] describes any of
//! the families and [`spec::LshSpec`] the whole multi-table index. (The
//! deprecated per-family `*Config` shims were removed in 0.3 — every
//! constructor routes through [`spec::FamilySpec::build`].)
//!
//! Two orthogonal extensions ride on the same machinery (PR 7):
//! [`FamilyKind::Sparse`] — the FastLSH-style sampled family ([`SparseE2lsh`]
//! / [`SparseSrp`], arXiv 2309.15479) — and `FamilySpec::precision`, which
//! switches a family's batch path onto the f32 SIMD-friendly kernels
//! (EXPERIMENTS.md §Precision). Every hasher carries its [`Precision`]; the
//! per-item [`HashFamily::hash`] and every batch entry point dispatch on it,
//! so insert-time and query-time codes always come from the same kernel.

mod planner;
pub mod spec;

pub use planner::{
    cp_condition_ratio, plan_cosine, plan_euclidean, plan_parameters, tt_condition_ratio,
    validity_report, LshPlan, ValidityReport,
};
pub use spec::{
    CoordinatorBuilder, FamilyKind, FamilySpec, IndexBuilder, LshSpec, NetSpec, SeedPolicy,
    ServingSpec, StoreSpec,
};

use crate::projection::{
    CpRademacher, GaussianDense, Precision, Projection, ProjectionMatrix, Scalar, SparseGaussian,
    TtRademacher,
};
use crate::rng::Rng;
use crate::stats;
use crate::tensor::AnyTensor;

/// A bank of K locality-sensitive hash functions.
pub trait HashFamily: Send + Sync {
    /// Hashes per signature (K).
    fn k(&self) -> usize;

    /// Hash a tensor to K integer codes, on the kernel selected by
    /// [`HashFamily::precision`] (per-item f32 hashing routes through the
    /// batch-of-one f32 kernel, so it is bit-identical to batched f32
    /// hashing — the same contract the f64 path keeps).
    fn hash(&self, x: &AnyTensor) -> Vec<i32> {
        match self.precision() {
            Precision::F64 => self.discretize(&self.project(x)),
            Precision::F32 => {
                let z = self.project_f32(x);
                let mut out = vec![0i32; z.len()];
                self.discretize_f32_into(&z, &mut out);
                out
            }
        }
    }

    /// Hash a batch of tensors: `out[b]` equals `hash(&xs[b])` bit-for-bit.
    ///
    /// Nested-Vec compatibility wrapper (one Vec per item) over the flat
    /// path; hot paths use [`HashFamily::hash_codes_into`] /
    /// [`crate::index::CodeMatrix`] instead.
    fn hash_batch(&self, xs: &[AnyTensor]) -> Vec<Vec<i32>> {
        match self.precision() {
            Precision::F64 => {
                let mut scratch = ProjectionMatrix::empty();
                self.project_batch_into(xs, &mut scratch);
                (0..xs.len()).map(|b| self.discretize(scratch.row(b))).collect()
            }
            Precision::F32 => {
                let mut scratch = ProjectionMatrix::<f32>::empty();
                let mut out = vec![0i32; xs.len() * self.k()];
                self.hash_codes_f32_into(xs, &mut scratch, &mut out, 0, self.k());
                out.chunks(self.k().max(1)).map(<[i32]>::to_vec).collect()
            }
        }
    }

    /// Hash a batch straight into a strided flat code buffer: item `b`'s K
    /// codes land at `out[b·stride + offset ..][..K]`. This is the single
    /// code path behind every batched hash — [`HashFamily::hash_batch`] and
    /// [`crate::index::CodeMatrix`] both route through it, so flat and
    /// nested hashing are bit-identical by construction. `scratch` is the
    /// caller's reusable projection arena.
    fn hash_codes_into(
        &self,
        xs: &[AnyTensor],
        scratch: &mut ProjectionMatrix,
        out: &mut [i32],
        offset: usize,
        stride: usize,
    ) {
        self.project_batch_into(xs, scratch);
        let k = self.k();
        for b in 0..xs.len() {
            let dst = &mut out[b * stride + offset..b * stride + offset + k];
            self.discretize_into(scratch.row(b), dst);
        }
    }

    /// The f32 twin of [`HashFamily::hash_codes_into`]: projects the batch on
    /// the single-precision fast kernels into the caller's f32 arena and
    /// discretizes into the same strided code layout. The index and
    /// coordinator batch paths call this whenever
    /// [`HashFamily::precision`] is [`Precision::F32`].
    fn hash_codes_f32_into(
        &self,
        xs: &[AnyTensor],
        scratch: &mut ProjectionMatrix<f32>,
        out: &mut [i32],
        offset: usize,
        stride: usize,
    ) {
        self.project_batch_f32_into(xs, scratch);
        let k = self.k();
        for b in 0..xs.len() {
            let dst = &mut out[b * stride + offset..b * stride + offset + k];
            self.discretize_f32_into(scratch.row(b), dst);
        }
    }

    /// Which kernel precision this family hashes at. [`Precision::F64`]
    /// (the default) is the bit-exact reference path.
    fn precision(&self) -> Precision {
        Precision::F64
    }

    /// The K raw projections (pre-discretization) — multiprobe needs these.
    fn project(&self, x: &AnyTensor) -> Vec<f64>;

    /// Raw projections for a batch into a flat `(batch, K)` matrix;
    /// `out.row(b)` equals `project(&xs[b])` bit-for-bit. Default loops;
    /// hashers over batch-capable projection banks override to delegate to
    /// [`crate::projection::Projection::project_batch_into`], so families
    /// with a stacked parameter layout (CP factors, TT block-diagonal cores)
    /// hash a serving batch in one fattened pass per mode instead of one per
    /// item.
    fn project_batch_into(&self, xs: &[AnyTensor], out: &mut ProjectionMatrix) {
        out.reset(xs.len(), self.k());
        for (b, x) in xs.iter().enumerate() {
            let z = self.project(x);
            out.row_mut(b).copy_from_slice(&z);
        }
    }

    /// Raw projections for a batch; `out[b]` equals `project(&xs[b])`
    /// bit-for-bit. Nested-Vec compatibility wrapper over
    /// [`HashFamily::project_batch_into`].
    fn project_batch(&self, xs: &[AnyTensor]) -> Vec<Vec<f64>> {
        let mut out = ProjectionMatrix::empty();
        self.project_batch_into(xs, &mut out);
        out.into_rows()
    }

    /// Single-precision per-item projections (the f32 fast path). The
    /// default narrows the f64 reference once per element; hashers over a
    /// projection bank delegate to
    /// [`crate::projection::Projection::project_f32`], which routes through
    /// the batch-of-one f32 kernel for batch/per-item bit-consistency.
    fn project_f32(&self, x: &AnyTensor) -> Vec<f32> {
        self.project(x).iter().map(|&v| <f32 as Scalar>::from_f64(v)).collect()
    }

    /// Single-precision batch projections into a flat f32 arena;
    /// `out.row(b)` equals `project_f32(&xs[b])` bit-for-bit. Default narrows
    /// the f64 reference; bank-backed hashers delegate to the fused f32
    /// kernels.
    fn project_batch_f32_into(&self, xs: &[AnyTensor], out: &mut ProjectionMatrix<f32>) {
        out.reset(xs.len(), self.k());
        for (b, x) in xs.iter().enumerate() {
            let z = self.project_f32(x);
            out.row_mut(b).copy_from_slice(&z);
        }
    }

    /// Discretize raw projections into a caller-provided code row
    /// (`out.len() == z.len()`), allocation-free.
    fn discretize_into(&self, z: &[f64], out: &mut [i32]);

    /// Discretize single-precision projections. The default widens each
    /// element and reuses the f64 discretizer, so both precisions share one
    /// bucket grid — f32 codes can differ from f64 codes only where the
    /// projection drift crosses a bucket boundary (tests/precision.rs pins
    /// that disagreement rate).
    fn discretize_f32_into(&self, z: &[f32], out: &mut [i32]) {
        let widened: Vec<f64> = z.iter().map(|&v| f64::from(v)).collect();
        self.discretize_into(&widened, out);
    }

    /// Discretize raw projections into codes.
    fn discretize(&self, z: &[f64]) -> Vec<i32> {
        let mut out = vec![0i32; z.len()];
        self.discretize_into(z, &mut out);
        out
    }

    /// Stored parameter count (space column of Tables 1–2).
    fn param_count(&self) -> usize;

    /// Family name, e.g. "cp-e2lsh".
    fn name(&self) -> String;

    /// Analytic single-hash collision probability given the *distance proxy*:
    /// Euclidean distance r for E2LSH families, cosine similarity for SRP
    /// families. This is the `p(·)` of Theorems 4/6/8/10.
    fn analytic_collision(&self, proxy: f64) -> f64;

    /// True for E2LSH-style families (proxy = distance), false for SRP
    /// (proxy = cosine similarity).
    fn is_euclidean(&self) -> bool;

    /// Multiprobe: up to `probes` extra bucket signatures beyond the exact
    /// one, most-promising first. The default is a geometry-agnostic
    /// heuristic; families with discretizer state override it (E2LSH uses
    /// exact distances to the bucket boundaries via (b, w)).
    fn probe_signatures(&self, codes: &[i32], z: &[f64], probes: usize) -> Vec<u64> {
        if self.is_euclidean() {
            crate::index::e2lsh_probes(codes, z, probes)
        } else {
            crate::index::srp_probes(codes, z, probes)
        }
    }
}

// ---------------------------------------------------------------------------
// Generic hashers over a projection bank
// ---------------------------------------------------------------------------

/// E2LSH discretizer over any projection family:
/// `h_k(x) = ⌊(⟨P_k, x⟩ + b_k)/w⌋` (Eq. 3.3 / 4.1 / 4.20).
#[derive(Clone, Debug)]
pub struct E2lshHasher<P: Projection> {
    pub proj: P,
    pub b: Vec<f64>,
    pub w: f64,
    label: &'static str,
    precision: Precision,
}

impl<P: Projection> E2lshHasher<P> {
    /// Wrap a projection bank with fresh uniform offsets `b_k ∈ [0, w)`.
    pub fn wrap(proj: P, w: f64, seed: u64, label: &'static str) -> Self {
        assert!(w > 0.0, "bucket width must be positive");
        let mut rng = Rng::derive(seed, &[0xB0FF5E7]);
        let b = (0..proj.k()).map(|_| rng.uniform(0.0, w)).collect();
        E2lshHasher { proj, b, w, label, precision: Precision::F64 }
    }

    /// Wrap with explicit offsets (banding: a band family must carry the
    /// matching slice of the full bank's offsets).
    pub fn with_offsets(proj: P, b: Vec<f64>, w: f64, label: &'static str) -> Self {
        assert!(w > 0.0, "bucket width must be positive");
        assert_eq!(b.len(), proj.k(), "offsets must match bank width");
        E2lshHasher { proj, b, w, label, precision: Precision::F64 }
    }

    /// Select the kernel precision (builder style; the default is the
    /// bit-exact f64 reference).
    #[must_use]
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }
}

impl<P: Projection> HashFamily for E2lshHasher<P> {
    fn k(&self) -> usize {
        self.proj.k()
    }

    fn project(&self, x: &AnyTensor) -> Vec<f64> {
        self.proj.project(x)
    }

    fn project_batch_into(&self, xs: &[AnyTensor], out: &mut ProjectionMatrix) {
        self.proj.project_batch_into(xs, out);
    }

    fn project_f32(&self, x: &AnyTensor) -> Vec<f32> {
        self.proj.project_f32(x)
    }

    fn project_batch_f32_into(&self, xs: &[AnyTensor], out: &mut ProjectionMatrix<f32>) {
        self.proj.project_batch_f32_into(xs, out);
    }

    // floor(·) of a bucket position; the LSH code domain is i32 by
    // construction (w sized to the data scale), so the narrowing is the
    // discretizer's contract, not an accident.
    #[allow(clippy::cast_possible_truncation)]
    fn discretize_into(&self, z: &[f64], out: &mut [i32]) {
        for ((o, &v), &b) in out.iter_mut().zip(z).zip(&self.b) {
            *o = ((v + b) / self.w).floor() as i32;
        }
    }

    /// Widen each f32 projection and discretize on the *same* f64 grid
    /// `(b_k, w)` as the reference path — allocation-free. Sharing the grid
    /// means f32 and f64 codes can differ only where the projection drift
    /// crosses a bucket boundary.
    #[allow(clippy::cast_possible_truncation)] // same contract as discretize_into
    fn discretize_f32_into(&self, z: &[f32], out: &mut [i32]) {
        for ((o, &v), &b) in out.iter_mut().zip(z).zip(&self.b) {
            *o = ((f64::from(v) + b) / self.w).floor() as i32;
        }
    }

    fn precision(&self) -> Precision {
        self.precision
    }

    fn param_count(&self) -> usize {
        self.proj.param_count() + self.b.len()
    }

    fn name(&self) -> String {
        format!("{}-e2lsh", self.label)
    }

    fn analytic_collision(&self, r: f64) -> f64 {
        stats::e2lsh_collision_prob(r, self.w)
    }

    fn is_euclidean(&self) -> bool {
        true
    }

    /// Exact query-directed multiprobe (Lv et al.): for every coordinate,
    /// the distance from `z_k + b_k` to its lower/upper bucket boundary
    /// ranks the ±1 perturbations; the `probes` closest boundaries win.
    /// One scratch row is perturbed in place per probe — no per-probe clone.
    fn probe_signatures(&self, codes: &[i32], z: &[f64], probes: usize) -> Vec<u64> {
        let k = codes.len();
        let mut cands: Vec<(f64, usize, i32)> = Vec::with_capacity(2 * k);
        for i in 0..k {
            let pos = (z[i] + self.b[i]) / self.w - codes[i] as f64; // in [0,1)
            cands.push((pos, i, -1)); // distance to lower boundary
            cands.push((1.0 - pos, i, 1)); // distance to upper boundary
        }
        cands.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut scratch = codes.to_vec();
        cands
            .into_iter()
            .take(probes)
            .map(|(_, i, step)| {
                scratch[i] += step;
                let sig = crate::index::signature(&scratch);
                scratch[i] -= step;
                sig
            })
            .collect()
    }
}

/// SRP discretizer over any projection family: `h_k(x) = sgn(⟨P_k, x⟩)`
/// (Eq. 3.1 / 4.34 / 4.61).
#[derive(Clone, Debug)]
pub struct SrpHasher<P: Projection> {
    pub proj: P,
    label: &'static str,
    precision: Precision,
}

impl<P: Projection> SrpHasher<P> {
    pub fn wrap(proj: P, label: &'static str) -> Self {
        SrpHasher { proj, label, precision: Precision::F64 }
    }

    /// Select the kernel precision (builder style; the default is the
    /// bit-exact f64 reference).
    #[must_use]
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }
}

impl<P: Projection> HashFamily for SrpHasher<P> {
    fn k(&self) -> usize {
        self.proj.k()
    }

    fn project(&self, x: &AnyTensor) -> Vec<f64> {
        self.proj.project(x)
    }

    fn project_batch_into(&self, xs: &[AnyTensor], out: &mut ProjectionMatrix) {
        self.proj.project_batch_into(xs, out);
    }

    fn project_f32(&self, x: &AnyTensor) -> Vec<f32> {
        self.proj.project_f32(x)
    }

    fn project_batch_f32_into(&self, xs: &[AnyTensor], out: &mut ProjectionMatrix<f32>) {
        self.proj.project_batch_f32_into(xs, out);
    }

    fn discretize_into(&self, z: &[f64], out: &mut [i32]) {
        for (o, &v) in out.iter_mut().zip(z) {
            *o = i32::from(v > 0.0);
        }
    }

    /// Sign test straight on the f32 projections (`0.0f32 > 0.0` agrees with
    /// the widened comparison, so the f32 grid is exactly the f64 grid).
    fn discretize_f32_into(&self, z: &[f32], out: &mut [i32]) {
        for (o, &v) in out.iter_mut().zip(z) {
            *o = i32::from(v > 0.0);
        }
    }

    fn precision(&self) -> Precision {
        self.precision
    }

    fn param_count(&self) -> usize {
        self.proj.param_count()
    }

    fn name(&self) -> String {
        format!("{}-srp", self.label)
    }

    fn analytic_collision(&self, cosine: f64) -> f64 {
        stats::srp_collision_prob(cosine)
    }

    fn is_euclidean(&self) -> bool {
        false
    }
}

/// Marker traits so generic code can demand the right proxy semantics.
pub trait E2lshFamily: HashFamily {
    fn w(&self) -> f64;
}
pub trait SrpFamily: HashFamily {}

impl<P: Projection> E2lshFamily for E2lshHasher<P> {
    fn w(&self) -> f64 {
        self.w
    }
}
impl<P: Projection> SrpFamily for SrpHasher<P> {}

// ---------------------------------------------------------------------------
// The six concrete families
// ---------------------------------------------------------------------------

/// CP-E2LSH (Definition 10).
pub type CpE2lsh = E2lshHasher<CpRademacher>;
/// TT-E2LSH (Definition 11).
pub type TtE2lsh = E2lshHasher<TtRademacher>;
/// Naive baseline: reshape + E2LSH [11].
pub type NaiveE2lsh = E2lshHasher<GaussianDense>;
/// CP-SRP (Definition 12).
pub type CpSrp = SrpHasher<CpRademacher>;
/// TT-SRP (Definition 13).
pub type TtSrp = SrpHasher<TtRademacher>;
/// Naive baseline: reshape + SRP [6].
pub type NaiveSrp = SrpHasher<GaussianDense>;
/// Fast-E2LSH: sparse sampled-coordinate projections + E2LSH discretizer
/// (FastLSH-style, arXiv 2309.15479).
pub type SparseE2lsh = E2lshHasher<SparseGaussian>;
/// Fast-SRP: sparse sampled-coordinate projections + sign discretizer.
pub type SparseSrp = SrpHasher<SparseGaussian>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::CpTensor;
    use crate::workload::{pair_at_cosine, pair_at_distance, PairFormat};

    use crate::projection::Distribution;
    use std::sync::Arc;

    fn dims() -> Vec<usize> {
        vec![6, 6, 6]
    }

    /// All eight families at one (dims, rank, K, w, seed) point, via the
    /// single declarative constructor path.
    fn all_families(rank: usize, k: usize, w: f64, seed: u64) -> Vec<Arc<dyn HashFamily>> {
        [
            FamilySpec::e2lsh(FamilyKind::Cp, dims(), rank, k, w),
            FamilySpec::e2lsh(FamilyKind::Tt, dims(), rank, k, w),
            FamilySpec::srp(FamilyKind::Cp, dims(), rank, k),
            FamilySpec::srp(FamilyKind::Tt, dims(), rank, k),
            FamilySpec::e2lsh(FamilyKind::Naive, dims(), rank, k, w),
            FamilySpec::srp(FamilyKind::Naive, dims(), rank, k),
            FamilySpec::e2lsh(FamilyKind::Sparse, dims(), rank, k, w),
            FamilySpec::srp(FamilyKind::Sparse, dims(), rank, k),
        ]
        .iter()
        .map(|s| s.build(seed).unwrap())
        .collect()
    }

    #[test]
    fn hash_is_deterministic_and_sized() {
        let fam = FamilySpec::e2lsh(FamilyKind::Cp, dims(), 4, 12, 4.0).build(3).unwrap();
        let mut rng = Rng::new(100);
        let x = AnyTensor::Cp(CpTensor::random_gaussian(&mut rng, &dims(), 2));
        let h1 = fam.hash(&x);
        let h2 = fam.hash(&x);
        assert_eq!(h1, h2);
        assert_eq!(h1.len(), 12);
        assert_eq!(fam.name(), "cp-e2lsh");
    }

    #[test]
    fn srp_codes_are_bits() {
        let fam = FamilySpec::srp(FamilyKind::Tt, dims(), 3, 20).build(4).unwrap();
        let mut rng = Rng::new(101);
        let x = AnyTensor::Cp(CpTensor::random_gaussian(&mut rng, &dims(), 2));
        assert!(fam.hash(&x).iter().all(|&c| c == 0 || c == 1));
        assert!(!fam.is_euclidean());
    }

    #[test]
    fn all_families_agree_on_input_format_invariance() {
        let mut rng = Rng::new(102);
        let xc = CpTensor::random_gaussian(&mut rng, &dims(), 2);
        let variants = [
            AnyTensor::Cp(xc.clone()),
            AnyTensor::Tt(xc.to_tt()),
            AnyTensor::Dense(xc.materialize()),
        ];
        for fam in &all_families(3, 8, 4.0, 5) {
            let h0 = fam.hash(&variants[0]);
            for v in &variants[1..] {
                // Identical tensor in a different format must hash identically
                // (up to f32 boundary effects, which these seeds avoid).
                assert_eq!(fam.hash(v), h0, "family {}", fam.name());
            }
        }
    }

    #[test]
    fn hash_batch_equals_per_item_hash_for_all_families() {
        // Satellite acceptance: for a fixed seed, `hash_batch` must equal
        // per-item `hash` exactly, across all eight families and mixed ranks.
        let mut rng = Rng::new(105);
        let batch: Vec<AnyTensor> = (0..9)
            .map(|i| AnyTensor::Cp(CpTensor::random_gaussian(&mut rng, &dims(), 1 + i % 4)))
            .collect();
        let fams = all_families(3, 8, 4.0, 55);
        for fam in &fams {
            let hb = fam.hash_batch(&batch);
            assert_eq!(hb.len(), batch.len(), "family {}", fam.name());
            for (x, codes) in batch.iter().zip(&hb) {
                assert_eq!(&fam.hash(x), codes, "family {}", fam.name());
            }
        }
        // Empty batches are fine.
        assert!(fams[0].hash_batch(&[]).is_empty());
    }

    #[test]
    fn e2lsh_empirical_collision_tracks_analytic() {
        // Single-hash collision rate over many k at controlled distance.
        // N=3 puts the CLT exponent at D^(1/30) (Theorem 4), so convergence
        // is slow at small shapes — use 8^3 = 512 elements and a finite-shape
        // tolerance; tight-tolerance validation at scale is experiment F1.
        let k = 3000;
        let d = vec![8usize, 8, 8];
        let fam = FamilySpec::e2lsh(FamilyKind::Cp, d.clone(), 4, k, 4.0).build(7).unwrap();
        let mut rng = Rng::new(103);
        for &r in &[0.5f64, 2.0, 4.0] {
            let (x, y) = pair_at_distance(&mut rng, &d, r, PairFormat::Cp(2));
            let (hx, hy) = (fam.hash(&x), fam.hash(&y));
            let rate =
                hx.iter().zip(&hy).filter(|(a, b)| a == b).count() as f64 / k as f64;
            let expect = fam.analytic_collision(r);
            assert!(
                (rate - expect).abs() < 0.07,
                "r={r}: rate {rate} vs analytic {expect}"
            );
        }
    }

    #[test]
    fn srp_empirical_collision_tracks_analytic() {
        let k = 3000;
        let fam = FamilySpec::srp(FamilyKind::Cp, dims(), 4, k).build(8).unwrap();
        let mut rng = Rng::new(104);
        for &c in &[0.9f64, 0.5, 0.0, -0.5] {
            let (x, y) = pair_at_cosine(&mut rng, &dims(), c, PairFormat::Cp(2));
            let (hx, hy) = (fam.hash(&x), fam.hash(&y));
            let rate =
                hx.iter().zip(&hy).filter(|(a, b)| a == b).count() as f64 / k as f64;
            let expect = fam.analytic_collision(c);
            assert!(
                (rate - expect).abs() < 0.04,
                "cos={c}: rate {rate} vs analytic {expect}"
            );
        }
    }

    #[test]
    fn e2lsh_probe_signatures_rank_by_boundary_distance() {
        // Direct wrap: the test reads the concrete hasher's offsets.
        let fam = E2lshHasher::wrap(
            CpRademacher::generate(9, &dims(), 2, 3, Distribution::Rademacher),
            4.0,
            9,
            "cp",
        );
        // Choose z so that (z + b)/w sits at known fractional positions.
        let z: Vec<f64> = (0..3).map(|i| 4.0 * (i as f64 + 0.5) - fam.b[i]).collect();
        let codes = fam.discretize(&z);
        // All fractions are exactly 0.5 ⇒ every ±1 step is equidistant; ask
        // for all 6 probes and check they are exactly the single-step codes.
        let probes = fam.probe_signatures(&codes, &z, 6);
        assert_eq!(probes.len(), 6);
        let mut expected = Vec::new();
        for i in 0..3 {
            for step in [-1, 1] {
                let mut c = codes.clone();
                c[i] += step;
                expected.push(crate::index::signature(&c));
            }
        }
        for p in probes {
            assert!(expected.contains(&p));
        }
        // A coordinate close to its upper boundary must be probed first.
        let z2: Vec<f64> = vec![4.0 * 0.99 - fam.b[0], 4.0 * 0.5 - fam.b[1], 4.0 * 0.5 - fam.b[2]];
        let codes2 = fam.discretize(&z2);
        let first = fam.probe_signatures(&codes2, &z2, 1)[0];
        let mut up = codes2.clone();
        up[0] += 1;
        assert_eq!(first, crate::index::signature(&up));
    }

    #[test]
    fn space_ordering_matches_tables() {
        let d = dims();
        let (k, r) = (8usize, 4usize);
        let cp = FamilySpec::e2lsh(FamilyKind::Cp, d.clone(), r, k, 4.0).build(1).unwrap();
        let tt = FamilySpec::e2lsh(FamilyKind::Tt, d.clone(), r, k, 4.0).build(1).unwrap();
        let nv = FamilySpec::e2lsh(FamilyKind::Naive, d, r, k, 4.0).build(1).unwrap();
        assert!(cp.param_count() < tt.param_count());
        assert!(tt.param_count() < nv.param_count());
    }
}
