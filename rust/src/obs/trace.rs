//! Per-query span accounting: a [`QueryTrace`] rides along with each
//! in-flight query and accumulates how long every pipeline stage spent on
//! it, plus the pager traffic it caused.
//!
//! The trace is **write-only from the hot path** (relaxed atomic adds, no
//! locks) and deliberately lives outside [`crate::query::SearchStats`]:
//! stats are part of the answer and must stay bit-identical whether
//! tracing is on or off, while timings are wall-clock noise. The
//! coordinator folds finished traces into the per-stage histograms of
//! [`crate::coordinator::Metrics`] and hands the breakdown to the
//! slow-query log.

use std::sync::atomic::{AtomicU64, Ordering};

/// Stage timings (nanoseconds) and pager attribution for one query.
///
/// Gather/rerank spans are summed across shards, so on a multi-shard
/// index they measure CPU time spent on the query, not wall time (shards
/// are probed in parallel). Pager counters are deltas of the shared
/// per-shard pager counters taken around the probe, so under concurrent
/// queries they are attributed approximately — totals in
/// [`crate::coordinator::MetricsSnapshot`] always come from the exact
/// index-side counters.
#[derive(Debug, Default)]
pub struct QueryTrace {
    hash_ns: AtomicU64,
    gather_ns: AtomicU64,
    rerank_ns: AtomicU64,
    merge_ns: AtomicU64,
    pager_hits: AtomicU64,
    pager_misses: AtomicU64,
}

impl QueryTrace {
    pub fn new() -> Self {
        Self::default()
    }

    /// This query's share of its hash batch (batch time / batch size).
    pub fn add_hash_ns(&self, ns: u64) {
        self.hash_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Candidate generation on one shard.
    pub fn add_gather_ns(&self, ns: u64) {
        self.gather_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Policy re-rank on one shard.
    pub fn add_rerank_ns(&self, ns: u64) {
        self.rerank_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Cross-shard merge in the aggregator.
    pub fn add_merge_ns(&self, ns: u64) {
        self.merge_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Pager traffic observed while probing one shard.
    pub fn add_pager(&self, hits: u64, misses: u64) {
        self.pager_hits.fetch_add(hits, Ordering::Relaxed);
        self.pager_misses.fetch_add(misses, Ordering::Relaxed);
    }

    pub fn hash_us(&self) -> f64 {
        self.hash_ns.load(Ordering::Relaxed) as f64 / 1e3
    }

    pub fn gather_us(&self) -> f64 {
        self.gather_ns.load(Ordering::Relaxed) as f64 / 1e3
    }

    pub fn rerank_us(&self) -> f64 {
        self.rerank_ns.load(Ordering::Relaxed) as f64 / 1e3
    }

    pub fn merge_us(&self) -> f64 {
        self.merge_ns.load(Ordering::Relaxed) as f64 / 1e3
    }

    pub fn pager_hits(&self) -> u64 {
        self.pager_hits.load(Ordering::Relaxed)
    }

    pub fn pager_misses(&self) -> u64 {
        self.pager_misses.load(Ordering::Relaxed)
    }

    /// The slow-query log's stage-breakdown object.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("hash_us".to_string(), Json::Num(self.hash_us()));
        m.insert("gather_us".to_string(), Json::Num(self.gather_us()));
        m.insert("rerank_us".to_string(), Json::Num(self.rerank_us()));
        m.insert("merge_us".to_string(), Json::Num(self.merge_us()));
        m.insert("pager_hits".to_string(), Json::Num(self.pager_hits() as f64));
        m.insert(
            "pager_misses".to_string(),
            Json::Num(self.pager_misses() as f64),
        );
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_accumulates_and_converts_units() {
        let t = QueryTrace::new();
        t.add_hash_ns(1_500);
        t.add_gather_ns(2_000);
        t.add_gather_ns(3_000); // second shard folds in
        t.add_rerank_ns(500);
        t.add_merge_ns(250);
        t.add_pager(7, 3);
        assert!((t.hash_us() - 1.5).abs() < 1e-12);
        assert!((t.gather_us() - 5.0).abs() < 1e-12);
        assert!((t.rerank_us() - 0.5).abs() < 1e-12);
        assert!((t.merge_us() - 0.25).abs() < 1e-12);
        assert_eq!((t.pager_hits(), t.pager_misses()), (7, 3));
        let text = t.to_json().to_string_compact();
        assert!(text.contains("\"gather_us\":5"), "{text}");
        assert!(text.contains("\"pager_hits\":7"), "{text}");
    }
}
