//! Observability layer: per-query stage tracing, the leveled structured
//! event log, and the Prometheus exposition surface.
//!
//! Three pieces, all zero-dependency:
//!
//! * [`QueryTrace`] — per-query span accounting (hash / gather / rerank /
//!   merge durations, pager traffic) carried through the coordinator
//!   pipeline and folded into [`crate::coordinator::Metrics`] per-stage
//!   histograms. Timings never touch [`crate::query::SearchStats`]:
//!   answers are bit-identical with tracing on or off
//!   (`tests/observability.rs` proves it over the full QueryOpts grid).
//! * [`event`] — leveled JSONL event log ([`log`], [`recent_events`],
//!   `log_level=` config key) replacing ad-hoc `eprintln!`s across the
//!   serving stack with machine-parseable single-line JSON events.
//! * [`render_prometheus`] — `name{labels} value` text exposition of a
//!   [`crate::coordinator::MetricsSnapshot`], served over the
//!   `Request::Metrics` wire frame and the `tensorlsh metrics` CLI verb.

pub mod event;
pub mod prom;
pub mod trace;

pub use event::{log, recent_events, set_log_level, Event, Level};
pub use prom::render_prometheus;
pub use trace::QueryTrace;
