//! Prometheus text exposition of a [`MetricsSnapshot`].
//!
//! Deliberately minimal: every line is `name value` or
//! `name{labels} value` (Prometheus text format 0.0.4 without `# HELP` /
//! `# TYPE` comments, which scrapers treat as optional). Flat snapshot
//! fields map 1:1 to `tensorlsh_<field>`; the per-stage summaries become
//! one metric family per statistic with a `stage` label, so dashboards
//! can plot all stages of one statistic with a single selector.

use crate::coordinator::{MetricsSnapshot, StageStats};
use std::fmt::Write as _;

/// Render one scrape. Values are finite by construction (idle means are
/// defined as 0.0), so the output always parses as
/// `name{labels} value` lines.
pub fn render_prometheus(snap: &MetricsSnapshot) -> String {
    let mut out = String::new();
    let mut line = |name: &str, v: f64| {
        let _ = writeln!(out, "tensorlsh_{name} {v}");
    };
    line("queries", snap.queries as f64);
    line("qps", snap.qps);
    line("mean_candidates", snap.mean_candidates);
    line("mean_probes", snap.mean_probes);
    line("mean_reranked", snap.mean_reranked);
    line("fallbacks", snap.fallbacks as f64);
    line("mean_batch", snap.mean_batch);
    line("latency_p50_us", snap.p50_us);
    line("latency_p95_us", snap.p95_us);
    line("latency_p99_us", snap.p99_us);
    line("latency_mean_us", snap.mean_us);
    line("slow_queries", snap.slow_queries as f64);
    line("live_items", snap.live_items as f64);
    line("tombstoned", snap.tombstoned as f64);
    line("compactions_run", snap.compactions_run as f64);
    line("reclaimed_slots", snap.reclaimed_slots as f64);
    line("pager_hits", snap.pager_hits as f64);
    line("pager_misses", snap.pager_misses as f64);
    line("pager_evictions", snap.pager_evictions as f64);
    line("pager_resident_bytes", snap.pager_resident_bytes as f64);
    line("wal_fsyncs", snap.wal_fsyncs as f64);
    line("wal_fsync_us_total", snap.wal_fsync_us);
    for (stage, s) in [
        ("hash", &snap.stage_hash),
        ("gather", &snap.stage_gather),
        ("rerank", &snap.stage_rerank),
        ("merge", &snap.stage_merge),
        ("wire_encode", &snap.stage_wire_encode),
    ] {
        stage_lines(&mut out, stage, s);
    }
    out
}

fn stage_lines(out: &mut String, stage: &str, s: &StageStats) {
    for (stat, v) in [
        ("count", s.count as f64),
        ("mean_us", s.mean_us),
        ("p50_us", s.p50_us),
        ("p95_us", s.p95_us),
        ("p99_us", s.p99_us),
    ] {
        let _ = writeln!(out, "tensorlsh_stage_{stat}{{stage=\"{stage}\"}} {v}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every rendered line matches `name{labels} value` with a finite
    /// value — the same check the CI scrape step runs against a live
    /// server.
    #[test]
    fn rendered_text_parses_line_by_line() {
        let mut snap = crate::coordinator::Metrics::new().snapshot();
        snap.queries = 12;
        snap.qps = 345.625;
        snap.stage_gather = StageStats {
            count: 12,
            mean_us: 40.5,
            p50_us: 39.0,
            p95_us: 80.0,
            p99_us: 95.0,
        };
        let text = render_prometheus(&snap);
        let mut names = std::collections::BTreeSet::new();
        for l in text.lines() {
            let (name, value) = l.split_once(' ').expect("name value");
            assert!(
                name.chars().next().unwrap().is_ascii_alphabetic(),
                "metric name must start alphabetic: {l}"
            );
            if let Some((base, labels)) = name.split_once('{') {
                assert!(labels.ends_with('}'), "unclosed labels: {l}");
                assert!(!base.is_empty() && base.starts_with("tensorlsh_"));
            } else {
                assert!(name.starts_with("tensorlsh_"), "{l}");
            }
            let v: f64 = value.parse().expect("numeric value");
            assert!(v.is_finite(), "{l}");
            names.insert(name.to_string());
        }
        // The per-stage families the CI step asserts on are present.
        for stage in ["hash", "gather", "rerank", "merge", "wire_encode"] {
            assert!(names.contains(&format!("tensorlsh_stage_p99_us{{stage=\"{stage}\"}}")));
        }
        assert!(text.contains("tensorlsh_queries 12\n"));
        assert!(text.contains("tensorlsh_stage_mean_us{stage=\"gather\"} 40.5\n"));
    }
}
