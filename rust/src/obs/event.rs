//! Leveled structured event log: one JSON object per line on stderr
//! (JSONL), gated by a process-wide level threshold, with a bounded ring
//! buffer of recent events for in-process inspection.
//!
//! Every operational message the serving stack used to `eprintln!` goes
//! through here instead: machine-parseable (each line is a complete JSON
//! document), silenceable (`log_level=` config key, default `warn`), and
//! queryable after the fact ([`recent_events`] keeps the last
//! [`RING_CAP`] events regardless of the stderr threshold).

use crate::util::json::Json;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

/// Event severity. `Off` is a threshold-only value (nothing logs *at*
/// `Off`; setting it as the threshold silences stderr entirely).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
    Off = 4,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
            Level::Off => "off",
        }
    }

    /// Parse a `log_level=` config value.
    pub fn parse(s: &str) -> crate::error::Result<Level> {
        match s {
            "debug" => Ok(Level::Debug),
            "info" => Ok(Level::Info),
            "warn" => Ok(Level::Warn),
            "error" => Ok(Level::Error),
            "off" => Ok(Level::Off),
            other => Err(crate::error::Error::Config(format!(
                "unknown log level '{other}' (expected debug|info|warn|error|off)"
            ))),
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Debug,
            1 => Level::Info,
            2 => Level::Warn,
            3 => Level::Error,
            _ => Level::Off,
        }
    }
}

impl std::fmt::Display for Level {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One logged event: a short machine-matchable code plus typed fields.
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    pub level: Level,
    /// Stable snake_case code, e.g. `"compaction"`, `"drain_timeout"`.
    pub code: String,
    pub fields: BTreeMap<String, Json>,
}

impl Event {
    /// The JSONL form: `level`/`event` first-class, fields inlined.
    pub fn to_json(&self) -> Json {
        let mut m = self.fields.clone();
        m.insert("level".to_string(), Json::Str(self.level.name().to_string()));
        m.insert("event".to_string(), Json::Str(self.code.clone()));
        Json::Obj(m)
    }
}

/// Events kept in the recent-events ring.
pub const RING_CAP: usize = 256;

static THRESHOLD: AtomicU8 = AtomicU8::new(Level::Warn as u8);
static RING: Mutex<VecDeque<Event>> = Mutex::new(VecDeque::new());

/// Set the process-wide stderr threshold (events below it still land in
/// the ring). Default: [`Level::Warn`].
pub fn set_log_level(level: Level) {
    THRESHOLD.store(level as u8, Ordering::Relaxed);
}

/// The current stderr threshold.
pub fn log_level() -> Level {
    Level::from_u8(THRESHOLD.load(Ordering::Relaxed))
}

/// Log one structured event. The event always enters the ring buffer;
/// it is written to stderr (one compact JSON line, with a `ts_ms` unix
/// timestamp) only when `level` is at or above the configured threshold.
pub fn log(level: Level, code: &str, fields: &[(&str, Json)]) {
    debug_assert!(level != Level::Off, "Off is a threshold, not an event level");
    let event = Event {
        level,
        code: code.to_string(),
        fields: fields.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
    };
    {
        let mut ring = RING.lock().unwrap();
        if ring.len() == RING_CAP {
            ring.pop_front();
        }
        ring.push_back(event.clone());
    }
    if level >= log_level() && level != Level::Off {
        let mut json = match event.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        let ts_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as f64)
            .unwrap_or(0.0);
        json.insert("ts_ms".to_string(), Json::Num(ts_ms));
        eprintln!("{}", Json::Obj(json).to_string_compact());
    }
}

/// Convenience wrappers for the common levels.
pub fn debug(code: &str, fields: &[(&str, Json)]) {
    log(Level::Debug, code, fields);
}
pub fn info(code: &str, fields: &[(&str, Json)]) {
    log(Level::Info, code, fields);
}
pub fn warn(code: &str, fields: &[(&str, Json)]) {
    log(Level::Warn, code, fields);
}
pub fn error(code: &str, fields: &[(&str, Json)]) {
    log(Level::Error, code, fields);
}

/// Shorthand field constructors for call sites.
pub fn num(v: f64) -> Json {
    Json::Num(v)
}
pub fn str(v: impl Into<String>) -> Json {
    Json::Str(v.into())
}

/// Clone out the ring buffer, oldest first (at most [`RING_CAP`] events,
/// every level — the stderr threshold does not filter the ring).
pub fn recent_events() -> Vec<Event> {
    RING.lock().unwrap().iter().cloned().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_and_order() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
        assert!(Level::Warn < Level::Error);
        assert!(Level::Error < Level::Off);
        for l in [Level::Debug, Level::Info, Level::Warn, Level::Error, Level::Off] {
            assert_eq!(Level::parse(l.name()).unwrap(), l);
        }
        assert!(Level::parse("verbose").is_err());
    }

    #[test]
    fn events_land_in_ring_below_threshold() {
        // Default threshold is warn; a debug event must still be captured.
        debug("obs_test_ring", &[("n", num(3.0)), ("what", str("x"))]);
        let events = recent_events();
        let ev = events
            .iter()
            .rev()
            .find(|e| e.code == "obs_test_ring")
            .expect("event captured");
        assert_eq!(ev.level, Level::Debug);
        assert_eq!(ev.fields.get("n"), Some(&Json::Num(3.0)));
        let line = ev.to_json().to_string_compact();
        assert!(!line.contains('\n'), "JSONL events are single-line: {line}");
        let back = crate::util::json::parse(&line).unwrap();
        assert_eq!(back.get("event").unwrap(), &Json::Str("obs_test_ring".into()));
        assert_eq!(back.get("level").unwrap(), &Json::Str("debug".into()));
    }
}
