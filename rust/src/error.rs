//! Library error type.

use thiserror::Error;

/// Errors produced by tensor-lsh.
#[derive(Error, Debug)]
pub enum Error {
    /// Tensor shapes are incompatible for the requested operation.
    #[error("shape mismatch: {0}")]
    ShapeMismatch(String),

    /// A parameter is out of its valid domain.
    #[error("invalid parameter: {0}")]
    InvalidParameter(String),

    /// A numerical routine failed to converge or produced a degenerate value.
    #[error("numerical failure: {0}")]
    Numerical(String),

    /// Configuration file / CLI parse problem.
    #[error("config error: {0}")]
    Config(String),

    /// JSON parse problem (hand-rolled parser in `util::json`).
    #[error("json error: {0}")]
    Json(String),

    /// PJRT runtime problem (artifact missing, compile/execute failure).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator problem (channel closed, worker panicked, ...).
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

/// Library result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl From<anyhow::Error> for Error {
    fn from(e: anyhow::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}
