//! Library error type.
//!
//! Hand-rolled (no `thiserror`): the crate builds with zero external
//! dependencies so the whole stack compiles offline.

use std::fmt;

/// Errors produced by tensor-lsh.
#[derive(Debug)]
pub enum Error {
    /// Tensor shapes are incompatible for the requested operation.
    ShapeMismatch(String),

    /// A parameter is out of its valid domain.
    InvalidParameter(String),

    /// A declarative [`crate::lsh::spec::LshSpec`] / [`crate::lsh::spec::FamilySpec`]
    /// failed validation (bad numerics, metric/family mismatch, or a
    /// dims/rank combination outside the theorems' validity regime).
    InvalidSpec(String),

    /// A numerical routine failed to converge or produced a degenerate value.
    Numerical(String),

    /// Configuration file / CLI parse problem.
    Config(String),

    /// JSON parse problem (hand-rolled parser in `util::json`).
    Json(String),

    /// PJRT runtime problem (artifact missing, compile/execute failure, or
    /// the crate was built without the `pjrt` feature).
    Runtime(String),

    /// Coordinator problem (channel closed, worker panicked, ...).
    Coordinator(String),

    /// Underlying I/O failure.
    Io(std::io::Error),

    /// A durable-store artifact (snapshot segment or WAL record) or a wire
    /// frame failed structural validation: bad magic, CRC mismatch,
    /// truncated section, or internally inconsistent contents. The store
    /// and the network layer never panic on — or silently serve — damaged
    /// bytes; they return this instead.
    Corrupt(String),

    /// The serving stack shed this request under load (admission control
    /// or connection cap). Retryable: the request was refused before any
    /// work happened, not half-done.
    Busy(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::ShapeMismatch(m) => write!(f, "shape mismatch: {m}"),
            Error::InvalidParameter(m) => write!(f, "invalid parameter: {m}"),
            Error::InvalidSpec(m) => write!(f, "invalid spec: {m}"),
            Error::Numerical(m) => write!(f, "numerical failure: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Corrupt(m) => write!(f, "corrupt store data: {m}"),
            Error::Busy(m) => write!(f, "server busy: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Library result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_carry_context() {
        assert_eq!(
            Error::ShapeMismatch("a vs b".into()).to_string(),
            "shape mismatch: a vs b"
        );
        assert_eq!(Error::Config("bad key".into()).to_string(), "config error: bad key");
        assert_eq!(
            Error::InvalidSpec("k must be ≥ 1".into()).to_string(),
            "invalid spec: k must be ≥ 1"
        );
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(io.to_string().contains("gone"));
        assert_eq!(
            Error::Corrupt("bad crc".into()).to_string(),
            "corrupt store data: bad crc"
        );
        assert_eq!(
            Error::Busy("queue full".into()).to_string(),
            "server busy: queue full"
        );
    }

    #[test]
    fn io_source_is_preserved() {
        use std::error::Error as _;
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "disk").into();
        assert!(e.source().is_some());
        assert!(Error::Numerical("x".into()).source().is_none());
    }
}
