//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.

use crate::error::{Error, Result};
use crate::util::json::{parse, Json};
use std::collections::BTreeMap;
use std::path::Path;

/// Canonical serving shapes (mirrors `aot.CONFIG`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestConfig {
    pub n_modes: usize,
    pub d: usize,
    pub rank_in: usize,
    pub rank_proj: usize,
    pub k: usize,
    pub batch: usize,
}

impl ManifestConfig {
    /// Mode dimensions as a vec (uniform d across modes).
    pub fn dims(&self) -> Vec<usize> {
        vec![self.d; self.n_modes]
    }
}

/// One AOT artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// Parameter shapes in call order.
    pub input_order: Vec<Vec<usize>>,
    pub sha256: String,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub config: ManifestConfig,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load and validate a manifest file.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse_str(&text)
    }

    /// Parse from a JSON string.
    pub fn parse_str(text: &str) -> Result<Manifest> {
        let root = parse(text)?;
        let cfg = root.get("config")?;
        let config = ManifestConfig {
            n_modes: cfg.get("n_modes")?.as_usize()?,
            d: cfg.get("d")?.as_usize()?,
            rank_in: cfg.get("rank_in")?.as_usize()?,
            rank_proj: cfg.get("rank_proj")?.as_usize()?,
            k: cfg.get("k")?.as_usize()?,
            batch: cfg.get("batch")?.as_usize()?,
        };
        let mut artifacts = BTreeMap::new();
        for (name, entry) in root.get("artifacts")?.as_obj()? {
            let input_order = entry
                .get("input_order")?
                .as_arr()?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<Vec<_>>>()
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: entry.get("file")?.as_str()?.to_string(),
                    input_order,
                    sha256: entry
                        .get("sha256")
                        .map(|j| j.as_str().unwrap_or("").to_string())
                        .unwrap_or_default(),
                },
            );
        }
        Ok(Manifest { config, artifacts })
    }

    /// Fetch an artifact by name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("artifact '{name}' not in manifest")))
    }

    /// Names of all artifacts.
    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }

    /// Pretty JSON round-trip (for `tensorlsh info`).
    pub fn summary(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert(
            "config".to_string(),
            Json::Obj(BTreeMap::from([
                ("n_modes".into(), Json::Num(self.config.n_modes as f64)),
                ("d".into(), Json::Num(self.config.d as f64)),
                ("rank_in".into(), Json::Num(self.config.rank_in as f64)),
                ("rank_proj".into(), Json::Num(self.config.rank_proj as f64)),
                ("k".into(), Json::Num(self.config.k as f64)),
                ("batch".into(), Json::Num(self.config.batch as f64)),
            ])),
        );
        obj.insert(
            "artifacts".to_string(),
            Json::Arr(self.names().iter().map(|n| Json::Str(n.to_string())).collect()),
        );
        Json::Obj(obj).to_string_pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "config": {"n_modes": 3, "d": 8, "rank_in": 2, "rank_proj": 2, "k": 4, "batch": 2},
        "artifacts": {
            "cp_srp": {
                "file": "cp_srp.hlo.txt",
                "inputs": {"x_factors": [[2, 8, 2]]},
                "input_order": [[2, 8, 2], [2, 8, 2], [2, 8, 2], [4, 8, 2], [4, 8, 2], [4, 8, 2]],
                "output": {"codes": [2, 4], "dtype": "i32"},
                "sha256": "abc",
                "bytes": 100
            }
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse_str(SAMPLE).unwrap();
        assert_eq!(m.config.d, 8);
        assert_eq!(m.config.dims(), vec![8, 8, 8]);
        let a = m.artifact("cp_srp").unwrap();
        assert_eq!(a.file, "cp_srp.hlo.txt");
        assert_eq!(a.input_order.len(), 6);
        assert_eq!(a.input_order[3], vec![4, 8, 2]);
        assert!(m.artifact("nope").is_err());
        assert!(m.summary().contains("cp_srp"));
    }

    #[test]
    fn parses_real_manifest_if_present() {
        // Integration-ish: if `make artifacts` has run, the real manifest
        // must parse and contain the six families + projection entry.
        if let Some(dir) = crate::runtime::find_artifact_dir(None) {
            let m = Manifest::load(&dir).unwrap();
            for name in ["cp_e2lsh", "tt_e2lsh", "cp_srp", "tt_srp", "naive_e2lsh", "naive_srp"] {
                assert!(m.artifacts.contains_key(name), "missing {name}");
            }
        }
    }
}
