//! PJRT runtime: load and execute the AOT-compiled XLA artifacts.
//!
//! `make artifacts` (Python, build-time only) lowers the L2 hash pipelines
//! to HLO **text** under `artifacts/`; this module loads them with
//! `HloModuleProto::from_text_file`, compiles once per artifact on the PJRT
//! CPU client, and executes them from the serving hot path. Python is never
//! on the request path.
//!
//! The projection parameters are *inputs* to the HLO functions, so the Rust
//! side regenerates them with the same seeded RNG as the native hash path —
//! the two paths are numerically interchangeable (verified in
//! `rust/tests/runtime_hlo.rs`).

#[cfg(feature = "pjrt")]
mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
mod engine;
mod manifest;

pub use engine::{HashBatchInput, PjrtEngine};
pub use manifest::{ArtifactMeta, Manifest, ManifestConfig};

/// Default artifact directory, relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: explicit arg, `TENSOR_LSH_ARTIFACTS` env
/// var, or walk up from CWD looking for `artifacts/manifest.json`.
pub fn find_artifact_dir(explicit: Option<&str>) -> Option<std::path::PathBuf> {
    if let Some(dir) = explicit {
        let p = std::path::PathBuf::from(dir);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
        return None;
    }
    if let Ok(env) = std::env::var("TENSOR_LSH_ARTIFACTS") {
        let p = std::path::PathBuf::from(env);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut cur = std::env::current_dir().ok()?;
    for _ in 0..5 {
        let cand = cur.join(DEFAULT_ARTIFACT_DIR);
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !cur.pop() {
            break;
        }
    }
    None
}
