//! PJRT execution engine: compile-once, execute-per-batch.

// Not the precision-audited hash path: PJRT buffer sizes are checked against the manifest.
#![allow(clippy::cast_possible_truncation)]

use super::manifest::Manifest;
use crate::error::{Error, Result};
use crate::projection::{CpRademacher, TtRademacher};
use crate::tensor::{CpTensor, DenseTensor, TtTensor};
use std::collections::HashMap;
use std::path::PathBuf;

/// A batch of query tensors in the format the artifact expects.
pub enum HashBatchInput<'a> {
    /// CP-format queries (each rank = manifest `rank_in`).
    Cp(&'a [CpTensor]),
    /// TT-format queries (uniform rank = manifest `rank_in`).
    Tt(&'a [TtTensor]),
    /// Dense queries (flattened internally).
    Dense(&'a [DenseTensor]),
}

impl HashBatchInput<'_> {
    pub fn len(&self) -> usize {
        match self {
            HashBatchInput::Cp(v) => v.len(),
            HashBatchInput::Tt(v) => v.len(),
            HashBatchInput::Dense(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Compile-once PJRT engine over the artifact bundle.
///
/// Not `Sync`: PJRT executables are driven from whichever thread owns the
/// engine (the coordinator gives the hash stage a dedicated owner thread).
pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtEngine {
    /// Create a CPU PJRT client and parse the manifest. Artifacts compile
    /// lazily on first use (compilation is ~100 ms each).
    pub fn new(dir: &std::path::Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;
        Ok(PjrtEngine {
            client,
            manifest,
            dir: dir.to_path_buf(),
            executables: HashMap::new(),
        })
    }

    /// The artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform string (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for an artifact.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let meta = self.manifest.artifact(name)?.clone();
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| Error::Runtime(format!("load {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {name}: {e}")))?;
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Force compilation of every artifact (warmup).
    pub fn warmup(&mut self) -> Result<()> {
        for name in self.manifest.names().iter().map(|s| s.to_string()).collect::<Vec<_>>() {
            self.executable(&name)?;
        }
        Ok(())
    }

    fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| Error::Runtime(format!("execute {name}: {e}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("sync {name}: {e}")))?;
        result
            .to_tuple1()
            .map_err(|e| Error::Runtime(format!("untuple {name}: {e}")))
    }

    /// Hash a batch through one of the `cp_*` artifacts.
    ///
    /// `proj` supplies the K CP-Rademacher projection tensors (raw ±1
    /// factors; the kernel applies the 1/√R of Definition 6 itself).
    /// For `cp_e2lsh`, `b`/`w` are the offsets and bucket width; pass
    /// `None` for `cp_srp`. Returns per-query K-code rows.
    pub fn hash_cp(
        &mut self,
        name: &str,
        batch: &[CpTensor],
        proj: &CpRademacher,
        e2lsh: Option<(&[f64], f64)>,
    ) -> Result<Vec<Vec<i32>>> {
        let cfg = self.manifest.config.clone();
        let (n, d, rin, rpj, k) = (cfg.n_modes, cfg.d, cfg.rank_in, cfg.rank_proj, cfg.k);
        self.check_batch(batch.len(), cfg.batch)?;
        for t in batch {
            if t.dims() != cfg.dims() || t.rank() != rin {
                return Err(Error::ShapeMismatch(format!(
                    "cp batch item dims {:?} rank {} vs manifest dims {:?} rank {rin}",
                    t.dims(),
                    t.rank(),
                    cfg.dims()
                )));
            }
        }
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(2 * n + 2);
        // x factors: (B, d, rin) per mode; scale folded into mode 0.
        for mode in 0..n {
            let mut data = vec![0.0f32; cfg.batch * d * rin];
            for (bi, t) in batch.iter().enumerate() {
                let f = &t.factors[mode];
                let s = if mode == 0 { t.scale } else { 1.0 };
                for i in 0..d {
                    for r in 0..rin {
                        data[(bi * d + i) * rin + r] = s * f.get(i, r);
                    }
                }
            }
            inputs.push(lit3(&data, cfg.batch, d, rin)?);
        }
        // projection factors: (K, d, rpj) per mode, raw ±1.
        for mode in 0..n {
            let mut data = vec![0.0f32; k * d * rpj];
            for (ki, t) in proj.tensors.iter().enumerate() {
                let f = &t.factors[mode];
                for i in 0..d {
                    for r in 0..rpj {
                        data[(ki * d + i) * rpj + r] = f.get(i, r);
                    }
                }
            }
            inputs.push(lit3(&data, k, d, rpj)?);
        }
        if let Some((b, w)) = e2lsh {
            let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
            inputs.push(xla::Literal::vec1(&bf));
            inputs.push(xla::Literal::scalar(w as f32));
        }
        let out = self.execute(name, &inputs)?;
        split_codes(&out, batch.len(), k)
    }

    /// Hash a batch through one of the `tt_*` artifacts (TT queries +
    /// TT-Rademacher projections; 1/√(R^{N−1}) applied in-kernel).
    pub fn hash_tt(
        &mut self,
        name: &str,
        batch: &[TtTensor],
        proj: &TtRademacher,
        e2lsh: Option<(&[f64], f64)>,
    ) -> Result<Vec<Vec<i32>>> {
        let cfg = self.manifest.config.clone();
        let (n, d, rin, rpj, k) = (cfg.n_modes, cfg.d, cfg.rank_in, cfg.rank_proj, cfg.k);
        self.check_batch(batch.len(), cfg.batch)?;
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(2 * n + 2);
        for mode in 0..n {
            let (rp, rn) = tt_bonds(mode, n, rin);
            let mut data = vec![0.0f32; cfg.batch * rp * d * rn];
            for (bi, t) in batch.iter().enumerate() {
                let core = &t.cores[mode];
                if core.r0 != rp || core.r1 != rn || core.d != d {
                    return Err(Error::ShapeMismatch(format!(
                        "tt core {mode}: ({},{},{}) vs manifest ({rp},{d},{rn})",
                        core.r0, core.d, core.r1
                    )));
                }
                let s = if mode == 0 { t.scale } else { 1.0 };
                for (j, &v) in core.data.iter().enumerate() {
                    data[bi * rp * d * rn + j] = s * v;
                }
            }
            inputs.push(lit4(&data, cfg.batch, rp, d, rn)?);
        }
        for mode in 0..n {
            let (rp, rn) = tt_bonds(mode, n, rpj);
            let mut data = vec![0.0f32; k * rp * d * rn];
            for (ki, t) in proj.tensors.iter().enumerate() {
                let core = &t.cores[mode];
                for (j, &v) in core.data.iter().enumerate() {
                    data[ki * rp * d * rn + j] = v;
                }
            }
            inputs.push(lit4(&data, k, rp, d, rn)?);
        }
        if let Some((b, w)) = e2lsh {
            let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
            inputs.push(xla::Literal::vec1(&bf));
            inputs.push(xla::Literal::scalar(w as f32));
        }
        let out = self.execute(name, &inputs)?;
        split_codes(&out, batch.len(), k)
    }

    /// Hash a dense batch through a `naive_*` artifact with an explicit
    /// (K, D) projection matrix.
    pub fn hash_dense(
        &mut self,
        name: &str,
        batch: &[DenseTensor],
        proj_rows: &[Vec<f32>],
        e2lsh: Option<(&[f64], f64)>,
    ) -> Result<Vec<Vec<i32>>> {
        let cfg = self.manifest.config.clone();
        let dflat: usize = cfg.dims().iter().product();
        let k = cfg.k;
        self.check_batch(batch.len(), cfg.batch)?;
        let mut xdata = vec![0.0f32; cfg.batch * dflat];
        for (bi, t) in batch.iter().enumerate() {
            if t.data.len() != dflat {
                return Err(Error::ShapeMismatch(format!(
                    "dense item has {} elements, manifest needs {dflat}",
                    t.data.len()
                )));
            }
            xdata[bi * dflat..(bi + 1) * dflat].copy_from_slice(&t.data);
        }
        let mut pdata = vec![0.0f32; k * dflat];
        for (ki, row) in proj_rows.iter().enumerate() {
            pdata[ki * dflat..(ki + 1) * dflat].copy_from_slice(row);
        }
        let mut inputs = vec![
            xla::Literal::vec1(&xdata)
                .reshape(&[cfg.batch as i64, dflat as i64])
                .map_err(|e| Error::Runtime(e.to_string()))?,
            xla::Literal::vec1(&pdata)
                .reshape(&[k as i64, dflat as i64])
                .map_err(|e| Error::Runtime(e.to_string()))?,
        ];
        if let Some((b, w)) = e2lsh {
            let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
            inputs.push(xla::Literal::vec1(&bf));
            inputs.push(xla::Literal::scalar(w as f32));
        }
        let out = self.execute(name, &inputs)?;
        split_codes(&out, batch.len(), k)
    }

    fn check_batch(&self, got: usize, want: usize) -> Result<()> {
        if got == 0 || got > want {
            return Err(Error::InvalidParameter(format!(
                "batch size {got} out of range 1..={want} (pad/split at the coordinator)"
            )));
        }
        Ok(())
    }
}

fn tt_bonds(mode: usize, n: usize, rank: usize) -> (usize, usize) {
    (
        if mode == 0 { 1 } else { rank },
        if mode == n - 1 { 1 } else { rank },
    )
}

fn lit3(data: &[f32], a: usize, b: usize, c: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(&[a as i64, b as i64, c as i64])
        .map_err(|e| Error::Runtime(e.to_string()))
}

fn lit4(data: &[f32], a: usize, b: usize, c: usize, d: usize) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(&[a as i64, b as i64, c as i64, d as i64])
        .map_err(|e| Error::Runtime(e.to_string()))
}

/// Slice the (B_manifest, K) i32 output literal into `n_real` code rows.
fn split_codes(out: &xla::Literal, n_real: usize, k: usize) -> Result<Vec<Vec<i32>>> {
    let flat: Vec<i32> = out
        .to_vec::<i32>()
        .map_err(|e| Error::Runtime(format!("output to_vec<i32>: {e}")))?;
    Ok((0..n_real).map(|b| flat[b * k..(b + 1) * k].to_vec()).collect())
}
