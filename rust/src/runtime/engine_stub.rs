//! Stub PJRT engine, compiled when the `pjrt` feature is off.
//!
//! The real engine (`engine.rs`) needs the `xla` crate and an XLA toolchain,
//! neither of which is vendored, so the default build swaps in this stub
//! with the identical public surface. [`PjrtEngine::new`] always fails with
//! [`Error::Runtime`]; callers that handle engine-init failure (the
//! coordinator falls back to the native batched hash path, the PJRT tests
//! and benches skip) keep working unchanged.

use super::manifest::Manifest;
use crate::error::{Error, Result};
use crate::projection::{CpRademacher, TtRademacher};
use crate::tensor::{CpTensor, DenseTensor, TtTensor};

/// A batch of query tensors in the format the artifact expects.
pub enum HashBatchInput<'a> {
    /// CP-format queries (each rank = manifest `rank_in`).
    Cp(&'a [CpTensor]),
    /// TT-format queries (uniform rank = manifest `rank_in`).
    Tt(&'a [TtTensor]),
    /// Dense queries (flattened internally).
    Dense(&'a [DenseTensor]),
}

impl HashBatchInput<'_> {
    pub fn len(&self) -> usize {
        match self {
            HashBatchInput::Cp(v) => v.len(),
            HashBatchInput::Tt(v) => v.len(),
            HashBatchInput::Dense(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn unavailable() -> Error {
    Error::Runtime(
        "tensor-lsh was built without the `pjrt` feature; the PJRT backend is \
         unavailable (rebuild with `--features pjrt` and an `xla` dependency)"
            .into(),
    )
}

/// Feature-gated placeholder for the PJRT execution engine.
pub struct PjrtEngine {
    manifest: Manifest,
}

impl PjrtEngine {
    /// Always fails: the crate was built without PJRT support. The manifest
    /// is still parsed first so configuration errors surface identically.
    pub fn new(dir: &std::path::Path) -> Result<Self> {
        let _ = Manifest::load(dir)?;
        Err(unavailable())
    }

    /// The artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform string.
    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    /// Force compilation of every artifact (warmup).
    pub fn warmup(&mut self) -> Result<()> {
        Err(unavailable())
    }

    /// Hash a batch through one of the `cp_*` artifacts.
    pub fn hash_cp(
        &mut self,
        _name: &str,
        _batch: &[CpTensor],
        _proj: &CpRademacher,
        _e2lsh: Option<(&[f64], f64)>,
    ) -> Result<Vec<Vec<i32>>> {
        Err(unavailable())
    }

    /// Hash a batch through one of the `tt_*` artifacts.
    pub fn hash_tt(
        &mut self,
        _name: &str,
        _batch: &[TtTensor],
        _proj: &TtRademacher,
        _e2lsh: Option<(&[f64], f64)>,
    ) -> Result<Vec<Vec<i32>>> {
        Err(unavailable())
    }

    /// Hash a dense batch through a `naive_*` artifact.
    pub fn hash_dense(
        &mut self,
        _name: &str,
        _batch: &[DenseTensor],
        _proj_rows: &[Vec<f32>],
        _e2lsh: Option<(&[f64], f64)>,
    ) -> Result<Vec<Vec<i32>>> {
        Err(unavailable())
    }
}
