//! # tensor-lsh
//!
//! Production-grade implementation of **“Improving LSH via Tensorized Random
//! Projection”** (Verma & Pratap, 2024): locality-sensitive hash families for
//! tensor data under Euclidean distance (CP-E2LSH, TT-E2LSH) and cosine
//! similarity (CP-SRP, TT-SRP), plus the naive reshape-and-project baselines,
//! a multi-table ANN index, and a serving coordinator whose hash hot path can
//! execute either natively or through AOT-compiled XLA artifacts via PJRT.
//!
//! ## Layout
//!
//! Substrates (built from scratch — no external numeric crates):
//! * [`rng`] — deterministic splittable RNG, Rademacher/Gaussian samplers.
//! * [`linalg`] — dense matrices, QR, Jacobi SVD (f64 internals).
//! * [`tensor`] — dense / CP / TT tensors and all inner-product pairings at
//!   the paper's complexities (Tables 1–2).
//! * [`decomp`] — CP-ALS and TT-SVD so dense data can be ingested.
//! * [`stats`] — collision laws, normal CDF, KS test, confidence intervals.
//! * [`workload`] — synthetic corpora and controlled-distance pair generators.
//!
//! Core library:
//! * [`projection`] — CP/TT Rademacher, dense Gaussian, and sparse
//!   sampled-coordinate ([`projection::SparseGaussian`]) projection families.
//!   Batches project through the flat SoA path
//!   ([`projection::Projection::project_batch_into`] into a
//!   [`projection::ProjectionMatrix`] arena); both CP and TT banks keep
//!   stacked per-mode parameter layouts so one fattened pass per mode serves
//!   the whole batch. Every batch kernel is generic over
//!   [`projection::Scalar`]: f64 is the bit-exact reference, f32 the
//!   SIMD-friendly fast path selected by `FamilySpec::precision`.
//! * [`lsh`] — the eight hash families behind common traits + parameter
//!   planning, all constructed from the declarative [`lsh::spec::LshSpec`]
//!   (JSON round-trippable; fluent [`lsh::spec::IndexBuilder`] /
//!   [`lsh::spec::CoordinatorBuilder`] on top);
//!   [`lsh::HashFamily::hash_codes_into`] hashes whole serving batches into
//!   flat strided code buffers ([`lsh::HashFamily::hash_batch`] is the
//!   nested-Vec compatibility wrapper).
//! * [`index`] — multi-table LSH index with multiprobe and policy-driven
//!   re-ranking: the single-shard reference [`index::LshIndex`] and the
//!   concurrently readable, `&self`-insert [`index::ShardedLshIndex`] the
//!   serving stack runs on. Bulk builds and the serving hash stage move
//!   codes as one [`index::CodeMatrix`] per batch (codes + precomputed
//!   bucket signatures), consumed by slice (`insert_codes`,
//!   `candidates_from_codes`) rather than per-item vectors.
//! * [`query`] — the unified query API: plain-data [`query::Query`] /
//!   [`query::SearchResponse`] (per-query multiprobe override, candidate
//!   cap, [`query::RerankPolicy`], per-query [`query::SearchStats`]) and
//!   the [`query::Searcher`] trait implemented by both index structures
//!   and the coordinator.
//! * [`store`] — the durable layer: versioned, CRC-checked snapshot
//!   segments ([`index::LshIndex::save`] / [`index::ShardedLshIndex::save`],
//!   one segment per shard written in parallel) plus an append-only insert
//!   WAL behind [`store::Store`] — open = newest valid snapshot + WAL
//!   replay, bit-identical to the index that was saved; damage is a typed
//!   [`Error::Corrupt`], never a panic or a silently wrong index.
//! * [`runtime`] — PJRT loader/executor for the `artifacts/*.hlo.txt` bundle
//!   (stubbed out unless the `pjrt` feature is enabled).
//! * [`coordinator`] — request router, dynamic batcher, batched hash stage,
//!   shard-parallel scatter-gather worker pool, metrics; warm-starts from a
//!   [`store::Store`] and checkpoints on shutdown; the
//!   [`coordinator::Dispatcher`] lets any number of threads share one
//!   pipeline.
//! * [`net`] — std-only framed TCP front end: CRC-checked wire protocol,
//!   thread-per-connection [`net::Server`] with admission control and
//!   graceful drain, blocking [`net::Client`] whose answers are
//!   bit-identical to in-process search.
//! * [`obs`] — the observability layer: per-query stage tracing
//!   ([`obs::QueryTrace`]), the leveled JSONL event log ([`obs::event`]),
//!   and Prometheus text exposition ([`obs::render_prometheus`]) behind
//!   the `Metrics` wire frame and the `tensorlsh metrics` CLI verb.
//! * [`bench_harness`] — regenerators for every table/figure of the paper.
//!
//! ## Quickstart
//!
//! Everything builds from one declarative, JSON round-trippable
//! [`lsh::spec::LshSpec`]. Hash a low-rank CP tensor with CP-E2LSH (this
//! example is a compiled, executed doctest — `cargo test` runs it):
//!
//! ```
//! use tensor_lsh::prelude::*;
//!
//! let mut rng = Rng::new(42);
//! let x = CpTensor::random_gaussian(&mut rng, &[32, 32, 32], 8);
//! let fam = FamilySpec::e2lsh(FamilyKind::Cp, vec![32, 32, 32], 8, 16, 4.0).build(7)?;
//! let codes = fam.hash(&AnyTensor::Cp(x.clone()));
//! assert_eq!(codes.len(), 16);
//!
//! // Batched hashing is bit-identical to per-item hashing.
//! let batch = vec![AnyTensor::Cp(x.clone()), AnyTensor::Cp(x)];
//! assert_eq!(fam.hash_batch(&batch), vec![codes.clone(), codes]);
//! # Ok::<(), tensor_lsh::Error>(())
//! ```
//!
//! Build a sharded index with the fluent [`lsh::spec::IndexBuilder`] and
//! query it through the unified [`query::Query`] builder (queries and
//! inserts both take `&self`, so this scales across coordinator workers):
//!
//! ```
//! use tensor_lsh::prelude::*;
//!
//! let dims = vec![8usize, 8, 8];
//! let mut rng = Rng::new(7);
//! let items: Vec<AnyTensor> = (0..200)
//!     .map(|_| AnyTensor::Cp(CpTensor::random_gaussian(&mut rng, &dims, 2)))
//!     .collect();
//! // CP-SRP, rank 4, K=10 hashes per signature, L=8 tables.
//! let spec = LshSpec::cosine(FamilyKind::Cp, dims, 4, 10, 8).with_seed(100, 1);
//! let index = IndexBuilder::new(spec.clone()).shards(4).build_sharded_with(items.clone())?;
//! let resp = index.query(&Query::new(items[3].clone(), 5))?;
//! assert_eq!(resp.hits[0].id, 3); // an indexed item is its own nearest neighbor
//! assert!(resp.stats.candidates_examined >= 1); // and the response says what it cost
//!
//! // The recall/latency knobs are per *query*, not baked into the build:
//! // probe 4 extra buckets per table and cap the exact re-rank at 64
//! // candidates, on the same built index.
//! let tuned = Query::new(items[3].clone(), 5)
//!     .probes(4)
//!     .rerank(RerankPolicy::Budgeted(64));
//! assert_eq!(index.query(&tuned)?.hits[0].id, 3);
//!
//! // The spec round-trips through JSON, so the exact serving config can be
//! // stored, diffed, and rebuilt bit-identically (query opts round-trip
//! // the same way — that is what the coordinator protocol serializes).
//! assert_eq!(LshSpec::from_json_str(&spec.to_json_string())?, spec);
//! # Ok::<(), tensor_lsh::Error>(())
//! ```
//!
//! Let the planner pick K and L from the paper's collision laws (gated by
//! the theorems' validity conditions — see [`lsh::LshSpec::planned`]):
//!
//! ```
//! use tensor_lsh::prelude::*;
//!
//! let spec = LshSpec::cosine(FamilyKind::Cp, vec![64, 64, 64, 64], 2, 1, 1)
//!     .planned(10_000, 0.9, 0.3, 0.5)?; // n, near sim, far sim, delta
//! assert!(spec.family.k > 1 && spec.l >= 1);
//! # Ok::<(), tensor_lsh::Error>(())
//! ```
//!
//! A built index is durable: [`index::LshIndex::save`] snapshots it to one
//! checksummed segment file and [`index::LshIndex::load`] reconstructs a
//! **bit-identical** searcher (same buckets, same hits, same stats). The
//! serving stack's directory-level [`store::Store`] adds an insert WAL and
//! snapshot generations on top (this doctest runs under `cargo test`):
//!
//! ```
//! use tensor_lsh::prelude::*;
//!
//! let dims = vec![6usize, 6];
//! let mut rng = Rng::new(11);
//! let items: Vec<AnyTensor> = (0..50)
//!     .map(|_| AnyTensor::Cp(CpTensor::random_gaussian(&mut rng, &dims, 2)))
//!     .collect();
//! let spec = LshSpec::cosine(FamilyKind::Cp, dims, 3, 8, 4).with_seed(5, 1);
//! let index = IndexBuilder::new(spec).build_with(items.clone())?;
//!
//! let path = std::env::temp_dir().join("tensor_lsh_doctest.seg");
//! index.save(&path)?;
//! let loaded = LshIndex::load(&path)?;
//! let q = Query::new(items[9].clone(), 5);
//! let (a, b) = (index.query(&q)?, loaded.query(&q)?);
//! assert_eq!(a.hits, b.hits);   // identical hits…
//! assert_eq!(a.stats, b.stats); // …and identical per-query accounting
//! # std::fs::remove_file(&path).ok();
//! # Ok::<(), tensor_lsh::Error>(())
//! ```

pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod decomp;
pub mod error;
pub mod index;
pub mod linalg;
pub mod lsh;
pub mod net;
pub mod obs;
pub mod projection;
pub mod query;
pub mod rng;
pub mod runtime;
pub mod stats;
pub mod store;
pub mod tensor;
pub mod testutil;
pub mod util;
pub mod workload;

pub use error::{Error, Result};

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::coordinator::{Dispatcher, QueryRequest, QueryResponse};
    pub use crate::error::{Error, Result};
    pub use crate::net::{Client, NetConfig, Server};
    pub use crate::index::{
        CodeMatrix, HashScratch, IndexConfig, LshIndex, Metric, SearchResult, ShardedLshIndex,
    };
    pub use crate::lsh::{
        CoordinatorBuilder, E2lshFamily, FamilyKind, FamilySpec, HashFamily, IndexBuilder,
        LshSpec, NetSpec, SeedPolicy, ServingSpec, SrpFamily, StoreSpec,
    };
    pub use crate::lsh::{CpE2lsh, CpSrp, NaiveE2lsh, NaiveSrp, TtE2lsh, TtSrp};
    pub use crate::lsh::{SparseE2lsh, SparseSrp};
    pub use crate::store::Store;
    pub use crate::projection::{
        CpRademacher, GaussianDense, Precision, Projection, ProjectionMatrix, SparseGaussian,
        TtRademacher,
    };
    pub use crate::query::{
        Query, QueryOpts, RerankPolicy, SearchResponse, SearchStats, Searcher,
    };
    pub use crate::rng::Rng;
    pub use crate::tensor::{AnyTensor, CpTensor, DenseTensor, TtTensor};
}
