//! # tensor-lsh
//!
//! Production-grade implementation of **“Improving LSH via Tensorized Random
//! Projection”** (Verma & Pratap, 2024): locality-sensitive hash families for
//! tensor data under Euclidean distance (CP-E2LSH, TT-E2LSH) and cosine
//! similarity (CP-SRP, TT-SRP), plus the naive reshape-and-project baselines,
//! a multi-table ANN index, and a serving coordinator whose hash hot path can
//! execute either natively or through AOT-compiled XLA artifacts via PJRT.
//!
//! ## Layout
//!
//! Substrates (built from scratch — no external numeric crates):
//! * [`rng`] — deterministic splittable RNG, Rademacher/Gaussian samplers.
//! * [`linalg`] — dense matrices, QR, Jacobi SVD (f64 internals).
//! * [`tensor`] — dense / CP / TT tensors and all inner-product pairings at
//!   the paper's complexities (Tables 1–2).
//! * [`decomp`] — CP-ALS and TT-SVD so dense data can be ingested.
//! * [`stats`] — collision laws, normal CDF, KS test, confidence intervals.
//! * [`workload`] — synthetic corpora and controlled-distance pair generators.
//!
//! Core library:
//! * [`projection`] — CP/TT Rademacher and dense Gaussian projection families.
//! * [`lsh`] — the six hash families behind common traits + parameter planning.
//! * [`index`] — multi-table LSH index with multiprobe and exact re-ranking.
//! * [`runtime`] — PJRT loader/executor for the `artifacts/*.hlo.txt` bundle.
//! * [`coordinator`] — request router, dynamic batcher, worker pool, metrics.
//! * [`bench_harness`] — regenerators for every table/figure of the paper.
//!
//! ## Quickstart
//!
//! ```no_run
//! use tensor_lsh::prelude::*;
//!
//! let mut rng = Rng::new(42);
//! let x = CpTensor::random_gaussian(&mut rng, &[32, 32, 32], 8);
//! let fam = CpE2lsh::new(CpE2lshConfig {
//!     dims: vec![32, 32, 32], rank: 8, k: 16, w: 4.0, seed: 7,
//! });
//! let codes = fam.hash(&AnyTensor::Cp(x));
//! assert_eq!(codes.len(), 16);
//! ```

pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod decomp;
pub mod error;
pub mod index;
pub mod linalg;
pub mod lsh;
pub mod projection;
pub mod rng;
pub mod runtime;
pub mod stats;
pub mod tensor;
pub mod testutil;
pub mod util;
pub mod workload;

pub use error::{Error, Result};

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::error::{Error, Result};
    pub use crate::index::{IndexConfig, LshIndex, SearchResult};
    pub use crate::lsh::{
        CpE2lsh, CpE2lshConfig, CpSrp, CpSrpConfig, E2lshFamily, HashFamily, NaiveE2lsh,
        NaiveSrp, SrpFamily, TtE2lsh, TtE2lshConfig, TtSrp, TtSrpConfig,
    };
    pub use crate::projection::{CpRademacher, GaussianDense, Projection, TtRademacher};
    pub use crate::rng::Rng;
    pub use crate::tensor::{AnyTensor, CpTensor, DenseTensor, TtTensor};
}
