//! Blocking client for the wire protocol.
//!
//! One [`Client`] wraps one TCP connection and speaks strict
//! request/response: every call writes one frame and reads one frame.
//! `Busy` responses surface as the retryable [`Error::Busy`]; server-side
//! failures as [`Error::Coordinator`]; a malformed or unexpected frame as
//! [`Error::Corrupt`] (the connection should be abandoned after one).

use super::frame::{read_response, write_request, Request, Response};
use crate::coordinator::MetricsSnapshot;
use crate::error::{Error, Result};
use crate::query::{Query, SearchResponse, Searcher};
use crate::tensor::AnyTensor;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// A connected wire-protocol client.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    /// Connect with the default 30 s read / 10 s write timeouts.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Client> {
        let stream = TcpStream::connect(&addr)?;
        Client::wrap(stream)
    }

    /// Connect with a bound on the TCP handshake itself.
    pub fn connect_timeout(addr: impl ToSocketAddrs, timeout: Duration) -> Result<Client> {
        let mut last = None;
        for sock in addr.to_socket_addrs()? {
            match TcpStream::connect_timeout(&sock, timeout) {
                Ok(stream) => return Client::wrap(stream),
                Err(e) => last = Some(e),
            }
        }
        Err(match last {
            Some(e) => Error::Io(e),
            None => Error::InvalidParameter("address resolved to nothing".into()),
        })
    }

    fn wrap(stream: TcpStream) -> Result<Client> {
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        stream.set_write_timeout(Some(Duration::from_secs(10)))?;
        Ok(Client { stream })
    }

    /// Override the per-call socket timeouts (`None` blocks indefinitely).
    pub fn set_timeouts(
        &mut self,
        read: Option<Duration>,
        write: Option<Duration>,
    ) -> Result<()> {
        self.stream.set_read_timeout(read)?;
        self.stream.set_write_timeout(write)?;
        Ok(())
    }

    /// One round trip: write the request frame, read the response frame.
    fn call(&mut self, req: &Request) -> Result<Response> {
        write_request(&mut self.stream, req)?;
        match read_response(&mut self.stream)? {
            Some(Response::Busy(m)) => Err(Error::Busy(m)),
            Some(resp) => Ok(resp),
            None => Err(Error::Coordinator("server closed the connection".into())),
        }
    }

    fn unexpected(resp: Response, wanted: &str) -> Error {
        Error::Corrupt(format!(
            "protocol confusion: expected a {wanted} frame, got {}",
            resp.name()
        ))
    }

    /// Round-trip liveness probe; returns the measured latency.
    pub fn ping(&mut self) -> Result<Duration> {
        let t0 = Instant::now();
        match self.call(&Request::Ping)? {
            Response::Pong => Ok(t0.elapsed()),
            other => Err(Client::unexpected(other, "Pong")),
        }
    }

    /// Remote [`Searcher::search`]: hits and stats are bit-identical to the
    /// server's in-process answer.
    pub fn search(&mut self, q: &Query) -> Result<SearchResponse> {
        match self.call(&Request::Search(q.clone()))? {
            Response::Results(resp) => Ok(resp),
            Response::Error(m) => Err(Error::Coordinator(m)),
            other => Err(Client::unexpected(other, "Results")),
        }
    }

    /// Remote batched search; `out[b]` answers `qs[b]`.
    pub fn search_batch(&mut self, qs: &[Query]) -> Result<Vec<SearchResponse>> {
        match self.call(&Request::SearchBatch(qs.to_vec()))? {
            Response::BatchResults(resps) => {
                if resps.len() != qs.len() {
                    return Err(Error::Corrupt(format!(
                        "batch answered {} of {} queries",
                        resps.len(),
                        qs.len()
                    )));
                }
                Ok(resps)
            }
            Response::Error(m) => Err(Error::Coordinator(m)),
            other => Err(Client::unexpected(other, "BatchResults")),
        }
    }

    /// Durable remote insert; returns the id the store assigned.
    pub fn insert(&mut self, x: &AnyTensor) -> Result<u64> {
        match self.call(&Request::Insert(x.clone()))? {
            Response::Inserted(id) => Ok(id),
            Response::Error(m) => Err(Error::Coordinator(m)),
            other => Err(Client::unexpected(other, "Inserted")),
        }
    }

    /// Durable remote delete by id (tombstoned server-side, reclaimed by a
    /// later compaction).
    pub fn remove(&mut self, id: u64) -> Result<()> {
        match self.call(&Request::Remove(id))? {
            Response::Removed => Ok(()),
            Response::Error(m) => Err(Error::Coordinator(m)),
            other => Err(Client::unexpected(other, "Removed")),
        }
    }

    /// Durable remote in-place replace of an existing id's tensor.
    pub fn upsert(&mut self, id: u64, x: &AnyTensor) -> Result<()> {
        match self.call(&Request::Upsert(id, x.clone()))? {
            Response::Upserted => Ok(()),
            Response::Error(m) => Err(Error::Coordinator(m)),
            other => Err(Client::unexpected(other, "Upserted")),
        }
    }

    /// The server's live metrics snapshot.
    pub fn stats(&mut self) -> Result<MetricsSnapshot> {
        match self.call(&Request::Stats)? {
            Response::Stats(snap) => Ok(snap),
            Response::Error(m) => Err(Error::Coordinator(m)),
            other => Err(Client::unexpected(other, "Stats")),
        }
    }

    /// The server's metrics in Prometheus text exposition format, ready to
    /// print or hand to a scraper.
    pub fn metrics_text(&mut self) -> Result<String> {
        match self.call(&Request::Metrics)? {
            Response::MetricsText(text) => Ok(text),
            Response::Error(m) => Err(Error::Coordinator(m)),
            other => Err(Client::unexpected(other, "MetricsText")),
        }
    }

    /// Ask the server to drain and exit; `Ok` once it acknowledges.
    pub fn shutdown_server(&mut self) -> Result<()> {
        match self.call(&Request::Shutdown)? {
            Response::Bye => Ok(()),
            other => Err(Client::unexpected(other, "Bye")),
        }
    }
}

/// A `&mut`-free searcher view is deliberately **not** provided: one client
/// is one ordered connection. Share work across threads by opening one
/// client per thread (connections are cheap; the server multiplexes them
/// onto a single pipeline).
impl Searcher for std::sync::Mutex<Client> {
    fn search(&self, q: &Query) -> Result<SearchResponse> {
        self.lock().unwrap().search(q)
    }

    fn search_batch(&self, qs: &[Query]) -> Result<Vec<SearchResponse>> {
        self.lock().unwrap().search_batch(qs)
    }
}
