//! Layer-4 wire serving: a framed TCP front end for the coordinator.
//!
//! Zero-dependency (std-only) networking in three pieces:
//!
//! * [`frame`] — the length-prefixed, CRC-32-framed binary protocol
//!   (`TLSHNET\0` magic, explicit version, bounded lengths); payloads reuse
//!   the store's bit-exact tensor encoding and the spec/query JSON, so a
//!   [`crate::query::Query`] round-trips the wire unchanged.
//! * [`Server`] — thread-per-connection acceptor over a
//!   [`crate::coordinator::Dispatcher`], with a connection cap,
//!   admission-control shedding (typed `Busy`), per-connection timeouts,
//!   and graceful drain (in-flight answered, store checkpointed).
//! * [`Client`] — a blocking request/response client whose
//!   [`Client::search`] answers are bit-identical to in-process
//!   [`crate::query::Searcher::search`].
//!
//! Wired into serving via `ServingSpec::listen` ([`crate::lsh::NetSpec`])
//! and the `tensorlsh serve --listen` / `ping` / `remote-query` / `stop`
//! commands.

pub mod frame;

mod client;
mod server;

pub use client::Client;
pub use frame::{Request, Response, MAX_FRAME_LEN, NET_MAGIC, PROTOCOL_VERSION};
pub use server::{NetConfig, Server};
