//! The wire frame: length-prefixed, CRC-framed binary messages.
//!
//! Every message on a connection — either direction — is one frame:
//!
//! ```text
//! ┌──────────┬─────────┬──────┬─────────┬───────────────┬─────────┐
//! │ magic    │ version │ type │ len     │ payload       │ crc     │
//! │ 8 bytes  │ u32     │ u8   │ u32     │ `len` bytes   │ u32     │
//! │ TLSHNET\0│   = 1   │      │ ≤ 2^28  │               │ IEEE    │
//! └──────────┴─────────┴──────┴─────────┴───────────────┴─────────┘
//! ```
//!
//! all little-endian; the CRC-32 covers everything before it (header *and*
//! payload), the same discipline as `store/format.rs` sections. The reader
//! enforces, in order: magic, version (unknown versions are refused, they
//! are not "probably compatible"), then the length word **before any
//! allocation** — a damaged or hostile length cannot drive a huge `Vec`.
//! Every damage mode is a typed [`Error::Corrupt`]; a clean close at a
//! frame boundary is `Ok(None)`; a disconnect mid-frame is `Corrupt` too
//! (the peer vanished holding half a message).
//!
//! The frame *type* byte is deliberately not validated at this layer: a
//! CRC-valid frame with an unknown type is a well-formed message from a
//! newer peer, and the server answers it with a typed `Error` response
//! instead of killing the connection (forward compatibility); only
//! structural damage is fatal to the stream.
//!
//! Payloads reuse the crate's existing serialization: tensors travel in the
//! store's bit-exact binary encoding ([`crate::store::tensors`]), while
//! [`QueryOpts`], [`SearchStats`], and [`MetricsSnapshot`] travel as their
//! canonical JSON — so a query round-trips the wire unchanged and a remote
//! `SearchResponse` (ids, f64 score bits, stats) is bit-identical to the
//! in-process answer.

// Not the precision-audited hash path: wire length fields are validated against caps before narrowing.
#![allow(clippy::cast_possible_truncation)]

use crate::coordinator::MetricsSnapshot;
use crate::error::{Error, Result};
use crate::index::SearchResult;
use crate::query::{Query, QueryOpts, SearchResponse, SearchStats};
use crate::store::crc::Crc32;
use crate::store::format::{Reader, WriteLe};
use crate::store::tensors::{decode_tensor, encode_tensor};
use crate::tensor::AnyTensor;
use crate::util::json::{parse as parse_json, Json};
use std::io::{Read, Write};

/// Frame preamble; distinct from the store's segment/WAL magics so a file
/// fed to a socket (or vice versa) fails loudly on the first 8 bytes.
pub const NET_MAGIC: [u8; 8] = *b"TLSHNET\0";

/// Protocol version. Bumped on any incompatible frame or payload change;
/// readers refuse every version they do not know.
pub const PROTOCOL_VERSION: u32 = 1;

/// Hard cap on a frame payload (256 MiB) — checked against the length word
/// before the payload buffer is allocated.
pub const MAX_FRAME_LEN: u32 = 1 << 28;

/// Bytes before the payload: magic ‖ version ‖ type ‖ len.
pub const HEADER_LEN: usize = 8 + 4 + 1 + 4;

/// Frame type bytes. Requests have the high bit clear, responses set.
pub mod ftype {
    pub const PING: u8 = 1;
    pub const SEARCH: u8 = 2;
    pub const SEARCH_BATCH: u8 = 3;
    pub const INSERT: u8 = 4;
    pub const STATS: u8 = 5;
    pub const SHUTDOWN: u8 = 6;
    pub const REMOVE: u8 = 7;
    pub const UPSERT: u8 = 8;
    pub const METRICS: u8 = 9;

    pub const PONG: u8 = 0x81;
    pub const RESULTS: u8 = 0x82;
    pub const BATCH_RESULTS: u8 = 0x83;
    pub const INSERTED: u8 = 0x84;
    pub const STATS_RESULT: u8 = 0x85;
    pub const BUSY: u8 = 0x86;
    pub const ERROR: u8 = 0x87;
    pub const BYE: u8 = 0x88;
    pub const REMOVED: u8 = 0x89;
    pub const UPSERTED: u8 = 0x8A;
    pub const METRICS_TEXT: u8 = 0x8B;
}

/// A client→server message.
#[derive(Clone, Debug)]
pub enum Request {
    Ping,
    Search(Query),
    SearchBatch(Vec<Query>),
    Insert(AnyTensor),
    Stats,
    Shutdown,
    /// Durable delete by id (tombstoned, reclaimed by compaction).
    Remove(u64),
    /// Durable in-place replace of an existing id's tensor.
    Upsert(u64, AnyTensor),
    /// Prometheus text exposition of the server's metrics — the scrape
    /// frame behind `tensorlsh metrics <addr>`.
    Metrics,
}

/// A server→client message.
#[derive(Clone, Debug)]
pub enum Response {
    Pong,
    Results(SearchResponse),
    BatchResults(Vec<SearchResponse>),
    /// Id assigned to a durable insert.
    Inserted(u64),
    Stats(MetricsSnapshot),
    /// The request was shed by admission control — retryable, nothing ran.
    Busy(String),
    /// The request was understood but failed (or its type is unknown to
    /// this server); the connection stays usable.
    Error(String),
    /// Acknowledges `Shutdown`; the server is draining.
    Bye,
    /// Acknowledges a durable `Remove`.
    Removed,
    /// Acknowledges a durable `Upsert`.
    Upserted,
    /// Prometheus `name{labels} value` text answering `Metrics`.
    MetricsText(String),
}

impl Response {
    /// Frame-type name for diagnostics (payload-free, unlike `Debug`).
    pub fn name(&self) -> &'static str {
        match self {
            Response::Pong => "Pong",
            Response::Results(_) => "Results",
            Response::BatchResults(_) => "BatchResults",
            Response::Inserted(_) => "Inserted",
            Response::Stats(_) => "Stats",
            Response::Busy(_) => "Busy",
            Response::Error(_) => "Error",
            Response::Bye => "Bye",
            Response::Removed => "Removed",
            Response::Upserted => "Upserted",
            Response::MetricsText(_) => "MetricsText",
        }
    }
}

fn corrupt(msg: impl Into<String>) -> Error {
    Error::Corrupt(msg.into())
}

// ---------------------------------------------------------------------------
// payload pieces

fn put_json(out: &mut Vec<u8>, v: &Json) {
    let text = v.to_string_pretty();
    out.put_u32(text.len() as u32);
    out.put_bytes(text.as_bytes());
}

fn read_json(r: &mut Reader<'_>, what: &str) -> Result<Json> {
    let len = r.u32()? as usize;
    let bytes = r.take(len)?;
    let text = std::str::from_utf8(bytes)
        .map_err(|_| corrupt(format!("{what}: JSON is not UTF-8")))?;
    parse_json(text).map_err(|e| corrupt(format!("{what}: {e}")))
}

/// `[opts JSON][tensor]` — opts via the canonical [`QueryOpts`] JSON,
/// tensor via the store's bit-exact encoding.
pub fn encode_query(out: &mut Vec<u8>, q: &Query) {
    put_json(out, &q.opts.to_json());
    encode_tensor(out, &q.tensor);
}

pub fn decode_query(r: &mut Reader<'_>) -> Result<Query> {
    let opts = QueryOpts::from_json(&read_json(r, "query opts")?)
        .map_err(|e| corrupt(format!("query opts: {e}")))?;
    let tensor = decode_tensor(r)?;
    Ok(Query { tensor, opts })
}

/// `[u32 n_hits][(u64 id ‖ f64 score) × n][stats JSON]` — scores travel as
/// raw f64 bits, so remote hits compare bit-identical to local ones.
pub fn encode_search_response(out: &mut Vec<u8>, resp: &SearchResponse) {
    out.put_u32(resp.hits.len() as u32);
    for h in &resp.hits {
        out.put_u64(h.id as u64);
        out.put_f64(h.score);
    }
    put_json(out, &resp.stats.to_json());
}

pub fn decode_search_response(r: &mut Reader<'_>) -> Result<SearchResponse> {
    let n = r.u32()? as usize;
    // 16 bytes per hit: an honest count is bounded by what remains.
    if n.saturating_mul(16) > r.remaining() {
        return Err(corrupt(format!("hit count {n} exceeds the frame's remaining bytes")));
    }
    let mut hits = Vec::with_capacity(n);
    for _ in 0..n {
        let id = r.u64()? as usize;
        let score = r.f64()?;
        hits.push(SearchResult { id, score });
    }
    let stats = SearchStats::from_json(&read_json(r, "search stats")?)
        .map_err(|e| corrupt(format!("search stats: {e}")))?;
    Ok(SearchResponse { hits, stats })
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.put_u32(s.len() as u32);
    out.put_bytes(s.as_bytes());
}

fn read_str(r: &mut Reader<'_>, what: &str) -> Result<String> {
    let len = r.u32()? as usize;
    let bytes = r.take(len)?;
    std::str::from_utf8(bytes)
        .map(|s| s.to_string())
        .map_err(|_| corrupt(format!("{what}: message is not UTF-8")))
}

// ---------------------------------------------------------------------------
// message ⇄ (type byte, payload)

impl Request {
    pub fn frame_type(&self) -> u8 {
        match self {
            Request::Ping => ftype::PING,
            Request::Search(_) => ftype::SEARCH,
            Request::SearchBatch(_) => ftype::SEARCH_BATCH,
            Request::Insert(_) => ftype::INSERT,
            Request::Stats => ftype::STATS,
            Request::Shutdown => ftype::SHUTDOWN,
            Request::Remove(_) => ftype::REMOVE,
            Request::Upsert(_, _) => ftype::UPSERT,
            Request::Metrics => ftype::METRICS,
        }
    }

    pub fn encode_payload(&self, out: &mut Vec<u8>) {
        match self {
            Request::Ping | Request::Stats | Request::Shutdown | Request::Metrics => {}
            Request::Search(q) => encode_query(out, q),
            Request::SearchBatch(qs) => {
                out.put_u32(qs.len() as u32);
                for q in qs {
                    encode_query(out, q);
                }
            }
            Request::Insert(x) => encode_tensor(out, x),
            Request::Remove(id) => out.put_u64(*id),
            Request::Upsert(id, x) => {
                out.put_u64(*id);
                encode_tensor(out, x);
            }
        }
    }

    /// Decode a CRC-verified frame into a request. An unknown type byte is
    /// an error here, but the caller (the server) answers it with a typed
    /// `Error` *response* rather than closing the stream.
    pub fn decode(frame_type: u8, payload: &[u8]) -> Result<Request> {
        let mut r = Reader::new(payload, "net request");
        let req = match frame_type {
            ftype::PING => Request::Ping,
            ftype::STATS => Request::Stats,
            ftype::SHUTDOWN => Request::Shutdown,
            ftype::METRICS => Request::Metrics,
            ftype::SEARCH => Request::Search(decode_query(&mut r)?),
            ftype::SEARCH_BATCH => {
                let n = r.u32()? as usize;
                if n > r.remaining() {
                    return Err(corrupt(format!(
                        "batch count {n} exceeds the frame's remaining bytes"
                    )));
                }
                let mut qs = Vec::with_capacity(n);
                for _ in 0..n {
                    qs.push(decode_query(&mut r)?);
                }
                Request::SearchBatch(qs)
            }
            ftype::INSERT => Request::Insert(decode_tensor(&mut r)?),
            ftype::REMOVE => Request::Remove(r.u64()?),
            ftype::UPSERT => {
                let id = r.u64()?;
                Request::Upsert(id, decode_tensor(&mut r)?)
            }
            other => return Err(corrupt(format!("unknown request frame type {other:#04x}"))),
        };
        if !r.is_empty() {
            return Err(corrupt(format!("request frame has {} trailing bytes", r.remaining())));
        }
        Ok(req)
    }
}

impl Response {
    pub fn frame_type(&self) -> u8 {
        match self {
            Response::Pong => ftype::PONG,
            Response::Results(_) => ftype::RESULTS,
            Response::BatchResults(_) => ftype::BATCH_RESULTS,
            Response::Inserted(_) => ftype::INSERTED,
            Response::Stats(_) => ftype::STATS_RESULT,
            Response::Busy(_) => ftype::BUSY,
            Response::Error(_) => ftype::ERROR,
            Response::Bye => ftype::BYE,
            Response::Removed => ftype::REMOVED,
            Response::Upserted => ftype::UPSERTED,
            Response::MetricsText(_) => ftype::METRICS_TEXT,
        }
    }

    pub fn encode_payload(&self, out: &mut Vec<u8>) {
        match self {
            Response::Pong | Response::Bye | Response::Removed | Response::Upserted => {}
            Response::Results(resp) => encode_search_response(out, resp),
            Response::BatchResults(resps) => {
                out.put_u32(resps.len() as u32);
                for resp in resps {
                    encode_search_response(out, resp);
                }
            }
            Response::Inserted(id) => out.put_u64(*id),
            Response::Stats(snap) => put_json(out, &snap.to_json()),
            Response::Busy(m) | Response::Error(m) => put_str(out, m),
            Response::MetricsText(text) => put_str(out, text),
        }
    }

    pub fn decode(frame_type: u8, payload: &[u8]) -> Result<Response> {
        let mut r = Reader::new(payload, "net response");
        let resp = match frame_type {
            ftype::PONG => Response::Pong,
            ftype::BYE => Response::Bye,
            ftype::REMOVED => Response::Removed,
            ftype::UPSERTED => Response::Upserted,
            ftype::RESULTS => Response::Results(decode_search_response(&mut r)?),
            ftype::BATCH_RESULTS => {
                let n = r.u32()? as usize;
                if n > r.remaining() {
                    return Err(corrupt(format!(
                        "batch count {n} exceeds the frame's remaining bytes"
                    )));
                }
                let mut resps = Vec::with_capacity(n);
                for _ in 0..n {
                    resps.push(decode_search_response(&mut r)?);
                }
                Response::BatchResults(resps)
            }
            ftype::INSERTED => Response::Inserted(r.u64()?),
            ftype::STATS_RESULT => Response::Stats(
                MetricsSnapshot::from_json(&read_json(&mut r, "stats")?)
                    .map_err(|e| corrupt(format!("stats: {e}")))?,
            ),
            ftype::BUSY => Response::Busy(read_str(&mut r, "busy")?),
            ftype::ERROR => Response::Error(read_str(&mut r, "error")?),
            ftype::METRICS_TEXT => {
                Response::MetricsText(read_str(&mut r, "metrics text")?)
            }
            other => return Err(corrupt(format!("unknown response frame type {other:#04x}"))),
        };
        if !r.is_empty() {
            return Err(corrupt(format!("response frame has {} trailing bytes", r.remaining())));
        }
        Ok(resp)
    }
}

// ---------------------------------------------------------------------------
// frame I/O

/// Write one frame (header ‖ payload ‖ crc) and flush.
pub fn write_frame(w: &mut impl Write, frame_type: u8, payload: &[u8]) -> Result<()> {
    if payload.len() as u64 > MAX_FRAME_LEN as u64 {
        return Err(Error::InvalidParameter(format!(
            "frame payload of {} bytes exceeds the {MAX_FRAME_LEN}-byte cap",
            payload.len()
        )));
    }
    let mut head = Vec::with_capacity(HEADER_LEN);
    head.put_bytes(&NET_MAGIC);
    head.put_u32(PROTOCOL_VERSION);
    head.put_u8(frame_type);
    head.put_u32(payload.len() as u32);
    let mut crc = Crc32::new();
    crc.update(&head);
    crc.update(payload);
    w.write_all(&head)?;
    w.write_all(payload)?;
    w.write_all(&crc.finish().to_le_bytes())?;
    w.flush()?;
    Ok(())
}

/// Read one frame. `Ok(None)` is a clean close (EOF at a frame boundary);
/// EOF anywhere inside a frame is [`Error::Corrupt`]. I/O errors (including
/// read timeouts) pass through as [`Error::Io`].
pub fn read_frame(r: &mut impl Read) -> Result<Option<(u8, Vec<u8>)>> {
    let mut first = [0u8; 1];
    // The first byte splits "peer closed between frames" from "peer died
    // mid-message".
    loop {
        match r.read(&mut first) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(Error::Io(e)),
        }
    }
    read_frame_rest(first[0], r).map(Some)
}

/// Read the remainder of a frame whose first byte is already in hand —
/// servers read the first byte separately under a short idle timeout, then
/// switch to the full read timeout for the body.
pub fn read_frame_rest(first: u8, r: &mut impl Read) -> Result<(u8, Vec<u8>)> {
    let mut head = [0u8; HEADER_LEN];
    head[0] = first;
    read_exact_or_corrupt(r, &mut head[1..], "frame header")?;
    if head[..8] != NET_MAGIC {
        return Err(corrupt(format!(
            "bad frame magic {:02x?} (expected {:02x?})",
            &head[..8],
            NET_MAGIC
        )));
    }
    let version = u32::from_le_bytes(head[8..12].try_into().unwrap());
    if version == 0 || version > PROTOCOL_VERSION {
        return Err(corrupt(format!(
            "unsupported protocol version {version} (this peer speaks {PROTOCOL_VERSION})"
        )));
    }
    let frame_type = head[12];
    let len = u32::from_le_bytes(head[13..17].try_into().unwrap());
    // Length sanity BEFORE the payload allocation.
    if len > MAX_FRAME_LEN {
        return Err(corrupt(format!("frame length {len} exceeds the {MAX_FRAME_LEN}-byte cap")));
    }
    let mut payload = vec![0u8; len as usize];
    read_exact_or_corrupt(r, &mut payload, "frame payload")?;
    let mut crc_bytes = [0u8; 4];
    read_exact_or_corrupt(r, &mut crc_bytes, "frame checksum")?;
    let stored = u32::from_le_bytes(crc_bytes);
    let mut crc = Crc32::new();
    crc.update(&head);
    crc.update(&payload);
    let computed = crc.finish();
    if stored != computed {
        return Err(corrupt(format!(
            "frame CRC mismatch: stored {stored:#010x}, computed {computed:#010x}"
        )));
    }
    Ok((frame_type, payload))
}

fn read_exact_or_corrupt(r: &mut impl Read, buf: &mut [u8], what: &str) -> Result<()> {
    match r.read_exact(buf) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            Err(corrupt(format!("{what}: connection closed mid-frame")))
        }
        Err(e) => Err(Error::Io(e)),
    }
}

/// Encode and write one request frame.
pub fn write_request(w: &mut impl Write, req: &Request) -> Result<()> {
    let mut payload = Vec::new();
    req.encode_payload(&mut payload);
    write_frame(w, req.frame_type(), &payload)
}

/// Encode and write one response frame.
pub fn write_response(w: &mut impl Write, resp: &Response) -> Result<()> {
    let mut payload = Vec::new();
    resp.encode_payload(&mut payload);
    write_frame(w, resp.frame_type(), &payload)
}

/// Read and decode one response frame (`Ok(None)` on clean close).
pub fn read_response(r: &mut impl Read) -> Result<Option<Response>> {
    match read_frame(r)? {
        None => Ok(None),
        Some((frame_type, payload)) => Response::decode(frame_type, &payload).map(Some),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;
    use crate::query::RerankPolicy;
    use crate::rng::Rng;
    use crate::tensor::CpTensor;
    use crate::testutil::proptest;
    use std::io::Cursor;

    fn sample_query(seed: u64) -> Query {
        let mut rng = Rng::new(seed);
        let tensor = AnyTensor::Cp(CpTensor::random_gaussian(&mut rng, &[4, 3], 2));
        Query::with_opts(
            tensor,
            QueryOpts::top_k(7)
                .with_probes(3)
                .with_max_candidates(50)
                .with_rerank(RerankPolicy::Budgeted(12))
                .with_exact_fallback(true)
                .with_dedup(false),
        )
    }

    fn sample_response(seed: u64) -> SearchResponse {
        let mut rng = Rng::new(seed);
        SearchResponse {
            hits: (0..5)
                .map(|i| SearchResult {
                    id: i * 17,
                    score: rng.normal() * 0.5 - 0.25,
                })
                .collect(),
            stats: SearchStats {
                candidates_generated: 31,
                candidates_examined: 20,
                probes_used: 3,
                tables_hit: 4,
                reranked: 12,
                exact_fallback: false,
            },
        }
    }

    fn frame_bytes_request(req: &Request) -> Vec<u8> {
        let mut buf = Vec::new();
        write_request(&mut buf, req).unwrap();
        buf
    }

    fn frame_bytes_response(resp: &Response) -> Vec<u8> {
        let mut buf = Vec::new();
        write_response(&mut buf, resp).unwrap();
        buf
    }

    fn decode_request_bytes(bytes: &[u8]) -> Result<Request> {
        let (t, payload) = read_frame(&mut Cursor::new(bytes))?.expect("one frame");
        Request::decode(t, &payload)
    }

    #[test]
    fn every_request_variant_roundtrips() {
        let snapshots = [
            Request::Ping,
            Request::Stats,
            Request::Shutdown,
            Request::Search(sample_query(1)),
            Request::SearchBatch(vec![sample_query(2), sample_query(3)]),
            Request::Insert(sample_query(4).tensor),
            Request::Remove(42),
            Request::Upsert(17, sample_query(10).tensor),
            Request::Metrics,
        ];
        for req in &snapshots {
            let bytes = frame_bytes_request(req);
            let back = decode_request_bytes(&bytes).unwrap();
            match (req, &back) {
                (Request::Ping, Request::Ping)
                | (Request::Stats, Request::Stats)
                | (Request::Shutdown, Request::Shutdown)
                | (Request::Metrics, Request::Metrics) => {}
                (Request::Search(a), Request::Search(b)) => {
                    assert_eq!(a.opts, b.opts);
                    assert!(crate::store::tensors_bit_equal(&a.tensor, &b.tensor));
                }
                (Request::SearchBatch(a), Request::SearchBatch(b)) => {
                    assert_eq!(a.len(), b.len());
                    for (x, y) in a.iter().zip(b) {
                        assert_eq!(x.opts, y.opts);
                        assert!(crate::store::tensors_bit_equal(&x.tensor, &y.tensor));
                    }
                }
                (Request::Insert(a), Request::Insert(b)) => {
                    assert!(crate::store::tensors_bit_equal(a, b));
                }
                (Request::Remove(a), Request::Remove(b)) => assert_eq!(a, b),
                (Request::Upsert(a, x), Request::Upsert(b, y)) => {
                    assert_eq!(a, b);
                    assert!(crate::store::tensors_bit_equal(x, y));
                }
                other => panic!("variant changed in transit: {other:?}"),
            }
        }
    }

    #[test]
    fn every_response_variant_roundtrips() {
        let metrics = Metrics::new();
        metrics.record_query(120.0, &sample_response(5).stats);
        let snapshots = [
            Response::Pong,
            Response::Bye,
            Response::Results(sample_response(6)),
            Response::BatchResults(vec![sample_response(7), sample_response(8)]),
            Response::Inserted(81),
            Response::Stats(metrics.snapshot()),
            Response::Busy("queue depth 4096".into()),
            Response::Error("no durable store attached".into()),
            Response::Removed,
            Response::Upserted,
            Response::MetricsText("tensorlsh_queries 1\ntensorlsh_qps 0\n".into()),
        ];
        for resp in &snapshots {
            let bytes = frame_bytes_response(resp);
            let back = read_response(&mut Cursor::new(&bytes)).unwrap().unwrap();
            match (resp, &back) {
                (Response::Pong, Response::Pong) | (Response::Bye, Response::Bye) => {}
                (Response::Results(a), Response::Results(b)) => assert_eq!(a, b),
                (Response::BatchResults(a), Response::BatchResults(b)) => assert_eq!(a, b),
                (Response::Inserted(a), Response::Inserted(b)) => assert_eq!(a, b),
                (Response::Stats(a), Response::Stats(b)) => assert_eq!(a, b),
                (Response::Busy(a), Response::Busy(b)) => assert_eq!(a, b),
                (Response::Error(a), Response::Error(b)) => assert_eq!(a, b),
                (Response::Removed, Response::Removed)
                | (Response::Upserted, Response::Upserted) => {}
                (Response::MetricsText(a), Response::MetricsText(b)) => assert_eq!(a, b),
                other => panic!("variant changed in transit: {other:?}"),
            }
        }
    }

    #[test]
    fn scores_roundtrip_bit_exact() {
        let orig = SearchResponse {
            hits: vec![
                SearchResult { id: 0, score: -0.0 },
                SearchResult { id: 1, score: f64::MIN_POSITIVE },
                SearchResult { id: 2, score: 1.0 / 3.0 },
            ],
            stats: SearchStats::default(),
        };
        let bytes = frame_bytes_response(&Response::Results(orig.clone()));
        match read_response(&mut Cursor::new(&bytes)).unwrap().unwrap() {
            Response::Results(back) => {
                for (a, b) in orig.hits.iter().zip(&back.hits) {
                    assert_eq!(a.score.to_bits(), b.score.to_bits());
                }
            }
            other => panic!("{}", other.name()),
        }
    }

    /// Any single-bit flip anywhere in a frame is a typed `Corrupt` (CRC,
    /// magic, version, or length check — whichever fires first), and any
    /// truncation is a mid-frame disconnect. Never a panic, never a frame
    /// that decodes to something else.
    #[test]
    fn prop_frame_damage_is_always_typed() {
        let pristine = frame_bytes_request(&Request::Search(sample_query(9)));
        assert!(decode_request_bytes(&pristine).is_ok());
        proptest("net frame damage", 256, |rng| {
            let mut bytes = pristine.clone();
            if rng.below(2) == 0 {
                let i = rng.below(bytes.len());
                bytes[i] ^= 1 << rng.below(8);
            } else {
                bytes.truncate(rng.below(bytes.len()));
            }
            match read_frame(&mut Cursor::new(&bytes)) {
                // Empty truncation = clean close; fine.
                Ok(None) => assert!(bytes.is_empty()),
                Ok(Some((t, payload))) => {
                    // CRC collisions are out of scope for single-bit flips;
                    // reaching here means the flip hit the *type byte space
                    // the CRC does cover*, so this cannot happen.
                    panic!("damaged frame decoded: type {t:#04x}, {} bytes", payload.len());
                }
                Err(Error::Corrupt(_)) => {}
                Err(other) => panic!("expected Corrupt, got {other}"),
            }
        });
    }

    #[test]
    fn unknown_version_is_refused_even_with_a_valid_crc() {
        // Hand-build a frame that is valid except for version = 2: the
        // version check must fire on its own, not lean on the CRC.
        let mut head = Vec::new();
        head.put_bytes(&NET_MAGIC);
        head.put_u32(PROTOCOL_VERSION + 1);
        head.put_u8(ftype::PING);
        head.put_u32(0);
        let mut crc = Crc32::new();
        crc.update(&head);
        let mut bytes = head;
        bytes.extend_from_slice(&crc.finish().to_le_bytes());
        match read_frame(&mut Cursor::new(&bytes)) {
            Err(Error::Corrupt(m)) => assert!(m.contains("version"), "{m}"),
            other => panic!("{other:?}"),
        }
        // Version 0 is refused too.
        let mut head = Vec::new();
        head.put_bytes(&NET_MAGIC);
        head.put_u32(0);
        head.put_u8(ftype::PING);
        head.put_u32(0);
        let mut crc = Crc32::new();
        crc.update(&head);
        let mut bytes = head;
        bytes.extend_from_slice(&crc.finish().to_le_bytes());
        assert!(matches!(
            read_frame(&mut Cursor::new(&bytes)),
            Err(Error::Corrupt(_))
        ));
    }

    #[test]
    fn oversized_length_word_is_rejected_before_allocation() {
        // A hostile header claiming a 3 GiB payload must fail on the length
        // check alone — no attempt to read (or allocate) the payload. The
        // empty cursor after the header proves no payload bytes exist; if
        // the length check did not fire first, this would be a mid-frame
        // EOF with a 3 GiB buffer already allocated.
        let mut head = Vec::new();
        head.put_bytes(&NET_MAGIC);
        head.put_u32(PROTOCOL_VERSION);
        head.put_u8(ftype::SEARCH);
        head.put_u32(u32::MAX - 1);
        match read_frame(&mut Cursor::new(&head)) {
            Err(Error::Corrupt(m)) => {
                assert!(m.contains("exceeds"), "length check must fire: {m}")
            }
            other => panic!("{other:?}"),
        }
        // Right at the cap is still within protocol (the payload then
        // legitimately fails as a mid-frame EOF, not an oversize).
        let mut head = Vec::new();
        head.put_bytes(&NET_MAGIC);
        head.put_u32(PROTOCOL_VERSION);
        head.put_u8(ftype::SEARCH);
        head.put_u32(MAX_FRAME_LEN);
        match read_frame(&mut Cursor::new(&head)) {
            Err(Error::Corrupt(m)) => assert!(m.contains("mid-frame"), "{m}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_frame_types_are_decode_errors_not_stream_errors() {
        // A CRC-valid frame with type 0x7f reads fine at the frame layer…
        let mut buf = Vec::new();
        write_frame(&mut buf, 0x7f, b"").unwrap();
        let (t, payload) = read_frame(&mut Cursor::new(&buf)).unwrap().unwrap();
        assert_eq!(t, 0x7f);
        // …and fails only at message decode, so a server can answer with a
        // typed Error response and keep the connection.
        assert!(matches!(Request::decode(t, &payload), Err(Error::Corrupt(_))));
        assert!(matches!(Response::decode(t, &payload), Err(Error::Corrupt(_))));
    }

    #[test]
    fn trailing_bytes_in_a_payload_are_rejected() {
        let mut payload = Vec::new();
        Request::Ping.encode_payload(&mut payload);
        payload.put_u8(0);
        assert!(matches!(
            Request::decode(ftype::PING, &payload),
            Err(Error::Corrupt(_))
        ));
    }

    #[test]
    fn clean_eof_between_frames_is_none() {
        assert!(read_frame(&mut Cursor::new(&[] as &[u8])).unwrap().is_none());
        // Two frames back to back read sequentially.
        let mut buf = Vec::new();
        write_request(&mut buf, &Request::Ping).unwrap();
        write_request(&mut buf, &Request::Stats).unwrap();
        let mut cur = Cursor::new(&buf);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap().0, ftype::PING);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap().0, ftype::STATS);
        assert!(read_frame(&mut cur).unwrap().is_none());
    }
}
