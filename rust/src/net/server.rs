//! Thread-per-connection TCP server over a [`Dispatcher`].
//!
//! The accept loop runs on its own thread with a non-blocking listener so
//! it can poll the stop flag; each accepted connection gets a handler
//! thread that reads frames under a read timeout, decodes requests, and
//! answers through the shared dispatcher. Three pressure valves keep a
//! misbehaving world from taking the pipeline down:
//!
//! * **Connection cap** — past `max_conns`, new sockets get one `Busy`
//!   frame and a close (counted as shed).
//! * **Admission control** — a search whose batch would push the
//!   dispatcher's in-flight depth past `max_inflight` is refused with
//!   `Busy` before it touches the pipeline; the client retries, the
//!   batcher queue stays shallow.
//! * **Request timeout** — an accepted search that outlives
//!   `request_timeout` is deregistered and answered with a typed `Error`.
//!
//! Shutdown (`Shutdown` frame or [`Server::request_shutdown`]) is a
//! graceful drain: the listener stops accepting, handlers finish the
//! request in hand and close, the dispatcher drains the pipeline under
//! [`crate::coordinator::DRAIN_DEADLINE`]-style bounds, and an attached
//! durable [`crate::store::Store`] is checkpointed — a kill between frames
//! never loses an acknowledged insert.

// Not the precision-audited hash path: wire length fields are validated against caps before narrowing.
#![allow(clippy::cast_possible_truncation)]

use super::frame::{read_frame_rest, write_response, Request, Response};
use crate::coordinator::{Coordinator, Dispatcher, MetricsSnapshot};
use crate::error::{Error, Result};
use crate::lsh::NetSpec;
use std::io::{BufWriter, Read};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Tunables for one listening server.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Concurrent connections before new sockets are shed with `Busy`.
    pub max_conns: usize,
    /// Per-connection idle/read budget; a peer silent this long is closed.
    pub read_timeout: Duration,
    /// Per-connection write budget (a peer that stops reading is closed).
    pub write_timeout: Duration,
    /// Admission-control depth: searches that would push the dispatcher's
    /// in-flight count past this are refused with `Busy`.
    pub max_inflight: usize,
    /// Budget for one accepted search/batch inside the pipeline.
    pub request_timeout: Duration,
    /// Bound on the shutdown drain (pipeline + store checkpoint).
    pub drain_deadline: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_conns: 64,
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(10),
            max_inflight: 1024,
            request_timeout: Duration::from_secs(30),
            drain_deadline: crate::coordinator::DRAIN_DEADLINE,
        }
    }
}

impl NetConfig {
    /// Adopt the serving spec's listener knobs (the spec's `addr` is the
    /// caller's concern — it names *where*, this names *how*).
    pub fn from_spec(spec: &NetSpec) -> NetConfig {
        NetConfig {
            max_conns: spec.max_conns,
            read_timeout: Duration::from_millis(spec.read_timeout_ms),
            write_timeout: Duration::from_millis(spec.write_timeout_ms),
            max_inflight: spec.max_inflight,
            ..NetConfig::default()
        }
    }
}

/// How long a handler blocks per first-byte read before re-checking the
/// stop flag; bounds shutdown latency for idle connections.
const IDLE_TICK: Duration = Duration::from_millis(50);

/// State shared by the accept loop and every connection handler.
struct Shared {
    dispatcher: Dispatcher,
    cfg: NetConfig,
    stop: AtomicBool,
    conns: AtomicUsize,
    shed: AtomicU64,
    conn_threads: Mutex<Vec<JoinHandle<()>>>,
}

/// A running wire server. Dropping it without [`Server::shutdown`] /
/// [`Server::wait`] detaches the threads — always consume it.
pub struct Server {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept_thread: JoinHandle<()>,
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// serving the coordinator's pipeline.
    pub fn start(coord: Coordinator, addr: &str, cfg: NetConfig) -> Result<Server> {
        let dispatcher = Dispatcher::start(coord)?;
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Coordinator(format!("cannot bind '{addr}': {e}")))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            dispatcher,
            cfg,
            stop: AtomicBool::new(false),
            conns: AtomicUsize::new(0),
            shed: AtomicU64::new(0),
            conn_threads: Mutex::new(Vec::new()),
        });
        let accept_thread = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || accept_loop(listener, shared))
        };
        Ok(Server { shared, addr: local, accept_thread })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Searches currently inside the pipeline.
    pub fn inflight(&self) -> usize {
        self.shared.dispatcher.inflight()
    }

    /// Requests and connections shed with `Busy` since start.
    pub fn shed_count(&self) -> u64 {
        self.shared.shed.load(Ordering::Relaxed)
    }

    /// Ask the server to drain (same effect as a `Shutdown` frame). Pair
    /// with [`Server::wait`].
    pub fn request_shutdown(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
    }

    /// Block until a shutdown is requested, then drain and return the
    /// final metrics snapshot.
    pub fn wait(self) -> MetricsSnapshot {
        while !self.shared.stop.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(20));
        }
        self.finish()
    }

    /// Request shutdown and drain.
    pub fn shutdown(self) -> MetricsSnapshot {
        self.request_shutdown();
        self.finish()
    }

    /// Drain: stop accepting, let handlers finish the request in hand,
    /// drain the pipeline, checkpoint the store.
    fn finish(self) -> MetricsSnapshot {
        let Server { shared, addr: _, accept_thread } = self;
        // The accept loop sees the flag, drops the listener (new
        // connections are refused by the OS from here on), and exits.
        let _ = accept_thread.join();
        let handles = std::mem::take(&mut *shared.conn_threads.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        let deadline = shared.cfg.drain_deadline;
        match Arc::try_unwrap(shared) {
            Ok(shared) => shared.dispatcher.shutdown(deadline),
            // Unreachable in practice (every clone lives in a joined
            // thread), but never hang shutdown on a leaked Arc: checkpoint
            // directly and report what we have.
            Err(arc) => {
                crate::obs::event::warn(
                    "shutdown_leak",
                    &[("where", crate::obs::event::str("net server shared state"))],
                );
                if let Some(store) = arc.dispatcher.store() {
                    if let Err(e) = store.checkpoint_if_dirty() {
                        crate::obs::event::error(
                            "checkpoint_failed",
                            &[
                                ("error", crate::obs::event::str(e.to_string())),
                                ("during", crate::obs::event::str("net server shutdown")),
                            ],
                        );
                    }
                }
                arc.dispatcher.metrics()
            }
        }
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return; // drops the listener: stop accepting
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let n = shared.conns.load(Ordering::SeqCst);
                if n >= shared.cfg.max_conns {
                    shed_connection(stream, &shared);
                    continue;
                }
                shared.conns.fetch_add(1, Ordering::SeqCst);
                let handler = {
                    let shared = Arc::clone(&shared);
                    std::thread::spawn(move || {
                        handle_connection(stream, &shared);
                        shared.conns.fetch_sub(1, Ordering::SeqCst);
                    })
                };
                let mut threads = shared.conn_threads.lock().unwrap();
                threads.retain(|h| !h.is_finished());
                threads.push(handler);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                crate::obs::event::warn(
                    "accept_failed",
                    &[("error", crate::obs::event::str(e.to_string()))],
                );
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

/// Over the connection cap: one `Busy` frame, then close.
fn shed_connection(stream: TcpStream, shared: &Shared) {
    shared.shed.fetch_add(1, Ordering::Relaxed);
    crate::obs::event::debug(
        "conn_shed",
        &[("max_conns", crate::obs::event::num(shared.cfg.max_conns as f64))],
    );
    let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
    let mut w = BufWriter::new(stream);
    let _ = write_response(
        &mut w,
        &Response::Busy(format!("connection limit of {} reached", shared.cfg.max_conns)),
    );
}

fn handle_connection(stream: TcpStream, shared: &Shared) {
    let _ = stream.set_nodelay(true);
    if stream.set_write_timeout(Some(shared.cfg.write_timeout)).is_err() {
        return;
    }
    let mut reader = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut writer = BufWriter::new(stream);
    let mut idle = Duration::ZERO;
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return; // graceful drain: nothing in hand, just close
        }
        // Short-timeout first byte: wake often enough to notice the stop
        // flag, without spinning.
        if reader.set_read_timeout(Some(IDLE_TICK)).is_err() {
            return;
        }
        let mut first = [0u8; 1];
        let got = match reader.read(&mut first) {
            Ok(0) => return, // clean close at a frame boundary
            Ok(_) => first[0],
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                idle += IDLE_TICK;
                if idle >= shared.cfg.read_timeout {
                    return; // idle peer: reclaim the slot
                }
                continue;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return,
        };
        idle = Duration::ZERO;
        // Mid-frame: the peer owes us a whole message within read_timeout.
        if reader.set_read_timeout(Some(shared.cfg.read_timeout)).is_err() {
            return;
        }
        let (frame_type, payload) = match read_frame_rest(got, &mut reader) {
            Ok(frame) => frame,
            Err(Error::Corrupt(m)) => {
                // Structural damage: the stream can no longer be trusted
                // (we may be mid-garbage). Best-effort typed answer, then
                // close.
                let _ = write_response(&mut writer, &Response::Error(m));
                return;
            }
            Err(_) => return, // I/O error or body timeout
        };
        // The frame itself was intact; everything from here is a typed
        // *response*, and the connection survives.
        let resp = match Request::decode(frame_type, &payload) {
            Ok(req) => match req {
                Request::Shutdown => {
                    let _ = write_response(&mut writer, &Response::Bye);
                    shared.stop.store(true, Ordering::SeqCst);
                    return;
                }
                other => answer(other, shared),
            },
            Err(e) => Response::Error(e.to_string()),
        };
        // Wire-encode span: serialization + socket write for search
        // answers (the payloads whose size scales with the result set)
        // lands in the `wire_encode` stage histogram.
        let t_wire = matches!(resp, Response::Results(_) | Response::BatchResults(_))
            .then(Instant::now);
        if write_response(&mut writer, &resp).is_err() {
            return;
        }
        if let Some(t0) = t_wire {
            shared.dispatcher.record_wire_encode(t0.elapsed().as_nanos() as f64 / 1e3);
        }
    }
}

/// Serve one decoded request (everything but `Shutdown`).
fn answer(req: Request, shared: &Shared) -> Response {
    match req {
        Request::Ping => Response::Pong,
        Request::Stats => Response::Stats(shared.dispatcher.metrics()),
        Request::Metrics => {
            Response::MetricsText(crate::obs::render_prometheus(&shared.dispatcher.metrics()))
        }
        Request::Insert(x) => match shared.dispatcher.store() {
            Some(store) => match store.insert(x) {
                Ok(id) => Response::Inserted(id as u64),
                Err(e) => Response::Error(format!("insert failed: {e}")),
            },
            None => Response::Error(
                "this server has no durable store attached (start with --store)".into(),
            ),
        },
        Request::Remove(id) => match shared.dispatcher.store() {
            Some(store) => match store.remove(id as usize) {
                Ok(()) => Response::Removed,
                Err(e) => Response::Error(format!("remove failed: {e}")),
            },
            None => Response::Error(
                "this server has no durable store attached (start with --store)".into(),
            ),
        },
        Request::Upsert(id, x) => match shared.dispatcher.store() {
            Some(store) => match store.upsert(id as usize, x) {
                Ok(()) => Response::Upserted,
                Err(e) => Response::Error(format!("upsert failed: {e}")),
            },
            None => Response::Error(
                "this server has no durable store attached (start with --store)".into(),
            ),
        },
        Request::Search(q) => match admit(shared, 1) {
            Err(m) => Response::Busy(m),
            Ok(()) => match shared.dispatcher.query_timeout(&q, Some(shared.cfg.request_timeout)) {
                Ok(resp) => Response::Results(resp),
                Err(e) => Response::Error(e.to_string()),
            },
        },
        Request::SearchBatch(qs) => match admit(shared, qs.len()) {
            Err(m) => Response::Busy(m),
            Ok(()) => match shared
                .dispatcher
                .query_batch_timeout(&qs, Some(shared.cfg.request_timeout))
            {
                Ok(resps) => Response::BatchResults(resps),
                Err(e) => Response::Error(e.to_string()),
            },
        },
        Request::Shutdown => unreachable!("handled by the connection loop"),
    }
}

/// Admission control: refuse work that would push the pipeline's in-flight
/// depth past the cap. Advisory (two racing admits can both pass), which is
/// fine — the cap bounds queue growth, it is not a hard invariant.
fn admit(shared: &Shared, n: usize) -> std::result::Result<(), String> {
    let depth = shared.dispatcher.inflight();
    if depth + n > shared.cfg.max_inflight {
        shared.shed.fetch_add(1, Ordering::Relaxed);
        crate::obs::event::debug(
            "request_shed",
            &[
                ("depth", crate::obs::event::num(depth as f64)),
                ("batch", crate::obs::event::num(n as f64)),
                ("max_inflight", crate::obs::event::num(shared.cfg.max_inflight as f64)),
            ],
        );
        Err(format!(
            "pipeline depth {depth} + {n} would exceed the {} in-flight cap",
            shared.cfg.max_inflight
        ))
    } else {
        Ok(())
    }
}
