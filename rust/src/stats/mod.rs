//! Statistical substrate: collision laws, normal CDF, goodness-of-fit tests
//! and confidence intervals used by the theory-validation experiments.

mod collision;
mod ks;
mod normal;

pub use collision::{e2lsh_collision_prob, e2lsh_collision_prob_quadrature, srp_collision_prob};
pub use ks::{ks_p_value, ks_statistic_normal, ks_statistic_with_cdf};
pub use normal::{erf, normal_cdf, normal_pdf};

/// Sample mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Standardized central moments (skewness, excess kurtosis).
pub fn skew_kurtosis(xs: &[f64]) -> (f64, f64) {
    let m = mean(xs);
    let n = xs.len() as f64;
    if n < 3.0 {
        return (0.0, 0.0);
    }
    let (mut m2, mut m3, mut m4) = (0.0, 0.0, 0.0);
    for &x in xs {
        let d = x - m;
        m2 += d * d;
        m3 += d * d * d;
        m4 += d * d * d * d;
    }
    m2 /= n;
    m3 /= n;
    m4 /= n;
    let sd = m2.sqrt();
    if sd == 0.0 {
        return (0.0, 0.0);
    }
    (m3 / (sd * sd * sd), m4 / (m2 * m2) - 3.0)
}

/// Wilson score interval for a binomial proportion at normal quantile `z`
/// (z = 1.96 for 95%). Returns (lo, hi).
pub fn wilson_interval(successes: usize, n: usize, z: f64) -> (f64, f64) {
    if n == 0 {
        return (0.0, 1.0);
    }
    let nf = n as f64;
    let p = successes as f64 / nf;
    let z2 = z * z;
    let denom = 1.0 + z2 / nf;
    let center = (p + z2 / (2.0 * nf)) / denom;
    let half = (z / denom) * ((p * (1.0 - p) / nf + z2 / (4.0 * nf * nf)).sqrt());
    ((center - half).max(0.0), (center + half).min(1.0))
}

/// Adaptive Simpson quadrature on [a, b].
pub fn adaptive_simpson(f: &dyn Fn(f64) -> f64, a: f64, b: f64, tol: f64) -> f64 {
    fn simpson(f: &dyn Fn(f64) -> f64, a: f64, fa: f64, b: f64, fb: f64) -> (f64, f64, f64) {
        let m = 0.5 * (a + b);
        let fm = f(m);
        ((b - a) / 6.0 * (fa + 4.0 * fm + fb), m, fm)
    }
    fn recurse(
        f: &dyn Fn(f64) -> f64,
        a: f64,
        fa: f64,
        b: f64,
        fb: f64,
        whole: f64,
        m: f64,
        fm: f64,
        tol: f64,
        depth: usize,
    ) -> f64 {
        let (left, lm, flm) = simpson(f, a, fa, m, fm);
        let (right, rm, frm) = simpson(f, m, fm, b, fb);
        if depth == 0 || (left + right - whole).abs() <= 15.0 * tol {
            left + right + (left + right - whole) / 15.0
        } else {
            recurse(f, a, fa, m, fm, left, lm, flm, tol / 2.0, depth - 1)
                + recurse(f, m, fm, b, fb, right, rm, frm, tol / 2.0, depth - 1)
        }
    }
    let (fa, fb) = (f(a), f(b));
    let (whole, m, fm) = simpson(f, a, fa, b, fb);
    recurse(f, a, fa, b, fb, whole, m, fm, tol, 40)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_known() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn wilson_contains_p_hat_and_shrinks() {
        let (lo1, hi1) = wilson_interval(50, 100, 1.96);
        assert!(lo1 < 0.5 && 0.5 < hi1);
        let (lo2, hi2) = wilson_interval(5000, 10000, 1.96);
        assert!(hi2 - lo2 < hi1 - lo1);
    }

    #[test]
    fn simpson_integrates_polynomials_exactly() {
        let v = adaptive_simpson(&|x| x * x * x, 0.0, 2.0, 1e-12);
        assert!((v - 4.0).abs() < 1e-10);
        let v = adaptive_simpson(&|x| (-x * x / 2.0).exp(), -8.0, 8.0, 1e-12);
        assert!((v - (2.0 * std::f64::consts::PI).sqrt()).abs() < 1e-8);
    }

    #[test]
    fn skew_kurtosis_of_symmetric_uniformish() {
        let xs: Vec<f64> = (0..10001).map(|i| i as f64 / 10000.0).collect();
        let (sk, ku) = skew_kurtosis(&xs);
        assert!(sk.abs() < 1e-10);
        assert!((ku - (-1.2)).abs() < 0.01); // uniform excess kurtosis = -6/5
    }
}
