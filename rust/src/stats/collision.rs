//! Analytic collision probabilities — the laws Theorems 4/6/8/10 transfer
//! from E2LSH [11] and SRP [6] to the tensorized families.

use super::normal::{normal_cdf, normal_pdf};

/// E2LSH collision probability `p(r; w)` (Eq. 3.4 / 4.17 / 4.33):
///
/// `p(r) = ∫₀ʷ (1/r)·f(t/r)·(1 − t/w) dt`, `f` the folded-normal density.
///
/// Closed form (Datar et al. [11]):
/// `p(r) = 1 − 2Φ(−w/r) − (2r/(√(2π)·w))·(1 − e^{−w²/(2r²)})`.
pub fn e2lsh_collision_prob(r: f64, w: f64) -> f64 {
    assert!(w > 0.0, "bucket width must be positive");
    if r <= 0.0 {
        return 1.0;
    }
    let c = w / r;
    let p = 1.0 - 2.0 * normal_cdf(-c)
        - (2.0 / ((2.0 * std::f64::consts::PI).sqrt() * c)) * (1.0 - (-c * c / 2.0).exp());
    p.clamp(0.0, 1.0)
}

/// The same probability by adaptive quadrature of Eq. 3.4 directly —
/// a cross-check used in tests and the F1 harness.
pub fn e2lsh_collision_prob_quadrature(r: f64, w: f64) -> f64 {
    if r <= 0.0 {
        return 1.0;
    }
    let f = |t: f64| (2.0 * normal_pdf(t / r) / r) * (1.0 - t / w);
    super::adaptive_simpson(&f, 0.0, w, 1e-12).clamp(0.0, 1.0)
}

/// SRP collision probability (Eq. 3.2 / 4.58 / 4.81): `1 − θ/π` for
/// cosine similarity `cos θ = s`.
pub fn srp_collision_prob(cosine: f64) -> f64 {
    let s = cosine.clamp(-1.0, 1.0);
    1.0 - s.acos() / std::f64::consts::PI
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_form_matches_quadrature() {
        for &w in &[1.0, 2.0, 4.0, 8.0] {
            for &r in &[0.1, 0.5, 1.0, 2.0, 5.0, 20.0] {
                let a = e2lsh_collision_prob(r, w);
                let b = e2lsh_collision_prob_quadrature(r, w);
                assert!((a - b).abs() < 1e-8, "w={w} r={r}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn e2lsh_prob_monotone_decreasing_in_r() {
        let w = 4.0;
        let mut prev = 1.0;
        for i in 1..200 {
            let r = i as f64 * 0.1;
            let p = e2lsh_collision_prob(r, w);
            assert!(p <= prev + 1e-12, "not monotone at r={r}");
            prev = p;
        }
    }

    #[test]
    fn e2lsh_limits() {
        assert!((e2lsh_collision_prob(0.0, 1.0) - 1.0).abs() < 1e-12);
        assert!(e2lsh_collision_prob(1e-6, 4.0) > 0.999);
        assert!(e2lsh_collision_prob(1e6, 4.0) < 1e-4);
    }

    #[test]
    fn srp_known_values() {
        assert!((srp_collision_prob(1.0) - 1.0).abs() < 1e-12);
        assert!((srp_collision_prob(-1.0) - 0.0).abs() < 1e-12);
        assert!((srp_collision_prob(0.0) - 0.5).abs() < 1e-12);
        // cos 60° = 0.5 -> θ = π/3 -> p = 2/3
        assert!((srp_collision_prob(0.5) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn srp_monotone_increasing_in_cosine() {
        let mut prev = 0.0;
        for i in 0..=100 {
            let c = -1.0 + 2.0 * i as f64 / 100.0;
            let p = srp_collision_prob(c);
            assert!(p >= prev - 1e-12);
            prev = p;
        }
    }
}
