//! Kolmogorov–Smirnov goodness-of-fit test (one-sample).

use super::normal::normal_cdf;

/// KS statistic of `samples` against an arbitrary CDF.
pub fn ks_statistic_with_cdf(samples: &[f64], cdf: &dyn Fn(f64) -> f64) -> f64 {
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len() as f64;
    let mut d = 0.0f64;
    for (i, &x) in xs.iter().enumerate() {
        let f = cdf(x);
        let lo = i as f64 / n;
        let hi = (i + 1) as f64 / n;
        d = d.max((f - lo).abs()).max((hi - f).abs());
    }
    d
}

/// KS statistic against the standard normal — the F3 experiment's metric for
/// Theorems 3/5 (⟨P,X⟩/‖X‖_F → N(0,1)).
pub fn ks_statistic_normal(samples: &[f64]) -> f64 {
    ks_statistic_with_cdf(samples, &normal_cdf)
}

/// Asymptotic KS p-value via the Kolmogorov distribution
/// `Q(λ) = 2 Σ_{k≥1} (−1)^{k−1} e^{−2k²λ²}` with the usual finite-n
/// refinement `λ = (√n + 0.12 + 0.11/√n)·D`.
pub fn ks_p_value(d: f64, n: usize) -> f64 {
    if n == 0 {
        return 1.0;
    }
    let sn = (n as f64).sqrt();
    let lambda = (sn + 0.12 + 0.11 / sn) * d;
    let mut sum = 0.0;
    let mut sign = 1.0;
    for k in 1..=100 {
        let term = (-2.0 * (k as f64) * (k as f64) * lambda * lambda).exp();
        sum += sign * term;
        sign = -sign;
        if term < 1e-12 {
            break;
        }
    }
    (2.0 * sum).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn normal_samples_pass() {
        let mut rng = Rng::new(60);
        let xs: Vec<f64> = (0..5000).map(|_| rng.normal()).collect();
        let d = ks_statistic_normal(&xs);
        assert!(d < 0.025, "D={d}");
        assert!(ks_p_value(d, xs.len()) > 0.01);
    }

    #[test]
    fn uniform_samples_fail_against_normal() {
        let mut rng = Rng::new(61);
        let xs: Vec<f64> = (0..5000).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let d = ks_statistic_normal(&xs);
        assert!(d > 0.05, "D={d}");
        assert!(ks_p_value(d, xs.len()) < 1e-6);
    }

    #[test]
    fn uniform_samples_pass_against_uniform_cdf() {
        let mut rng = Rng::new(62);
        let xs: Vec<f64> = (0..5000).map(|_| rng.next_f64()).collect();
        let d = ks_statistic_with_cdf(&xs, &|x| x.clamp(0.0, 1.0));
        assert!(d < 0.025, "D={d}");
    }

    #[test]
    fn p_value_decreases_with_d() {
        assert!(ks_p_value(0.01, 1000) > ks_p_value(0.05, 1000));
        assert!(ks_p_value(0.05, 1000) > ks_p_value(0.2, 1000));
    }
}
