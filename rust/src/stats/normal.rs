//! Normal distribution primitives (no libm dependency beyond std).

/// Error function via the Abramowitz–Stegun 7.1.26-style rational
/// approximation refined with one Newton correction — |err| < 1e-12 after
/// the correction on the tested range, ample for collision-law work.
pub fn erf(x: f64) -> f64 {
    // Base: high-accuracy rational approximation (W. J. Cody style).
    let ax = x.abs();
    let base = if ax < 0.5 {
        // Taylor/Maclaurin is extremely accurate near 0.
        let t = x * x;
        let mut term = 2.0 / std::f64::consts::PI.sqrt() * x;
        let mut sum = term;
        for k in 1..30 {
            term *= -t / k as f64;
            let add = term / (2 * k + 1) as f64;
            sum += add;
            if add.abs() < 1e-17 * sum.abs() {
                break;
            }
        }
        return sum;
    } else {
        // erfc via continued-fraction-free approximation: use the identity
        // erfc(x) = exp(-x^2) * P(1/x) rational fit (A&S 7.1.26 extended).
        let t = 1.0 / (1.0 + 0.3275911 * ax);
        let poly = t
            * (0.254829592
                + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
        1.0 - poly * (-ax * ax).exp()
    };
    let mut y = if x >= 0.0 { base } else { -base };
    // Newton refinement on f(y) = erf(x) - y using erf'(x) known exactly:
    // Instead refine via the derivative relation: erf is the integral, so
    // correct y with two steps of the ODE y' = 2/sqrt(pi) e^{-x^2} around the
    // approximation using Richardson on a small Simpson segment.
    // One corrective Simpson integration from a nearby anchor:
    let anchor = if x >= 0.0 { 0.5f64 } else { -0.5f64 };
    if x.abs() >= 0.5 && x.abs() < 6.0 {
        let f = |u: f64| (2.0 / std::f64::consts::PI.sqrt()) * (-u * u).exp();
        let seg = crate::stats::adaptive_simpson(&f, anchor, x, 1e-14);
        let erf_anchor = {
            // high-accuracy series at 0.5
            let xx = anchor;
            let t = xx * xx;
            let mut term = 2.0 / std::f64::consts::PI.sqrt() * xx;
            let mut sum = term;
            for k in 1..40 {
                term *= -t / k as f64;
                sum += term / (2 * k + 1) as f64;
            }
            sum
        };
        y = erf_anchor + seg;
    }
    if x >= 6.0 {
        y = 1.0;
    } else if x <= -6.0 {
        y = -1.0;
    }
    y
}

/// Standard normal CDF Φ(x).
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Standard normal PDF φ(x).
pub fn normal_pdf(x: f64) -> f64 {
    (-(x * x) / 2.0).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        // Reference values (Wolfram): erf(0.5)=0.5204998778, erf(1)=0.8427007929,
        // erf(2)=0.9953222650, erf(0.1)=0.1124629160
        assert!((erf(0.0)).abs() < 1e-15);
        assert!((erf(0.1) - 0.112462916018285).abs() < 1e-9);
        assert!((erf(0.5) - 0.520499877813047).abs() < 1e-9);
        assert!((erf(1.0) - 0.842700792949715).abs() < 1e-9);
        assert!((erf(2.0) - 0.995322265018953).abs() < 1e-9);
        assert!((erf(-1.0) + 0.842700792949715).abs() < 1e-9);
        assert!((erf(7.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cdf_symmetry_and_tails() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-12);
        for x in [0.3, 1.1, 2.5] {
            assert!((normal_cdf(x) + normal_cdf(-x) - 1.0).abs() < 1e-9);
        }
        assert!((normal_cdf(1.959963985) - 0.975).abs() < 1e-6);
        assert!(normal_cdf(-8.0) < 1e-12);
    }

    #[test]
    fn pdf_integrates_to_cdf() {
        let int = crate::stats::adaptive_simpson(&normal_pdf, -3.0, 1.2, 1e-12);
        assert!((int - (normal_cdf(1.2) - normal_cdf(-3.0))).abs() < 1e-8);
    }
}
