//! The unified query API: plain-data request/response types plus the
//! [`Searcher`] trait all three serving layers implement.
//!
//! The CP/TT hash families make signatures cheap, so at serving scale the
//! recall/latency trade-off lives almost entirely on the *query side*:
//! multiprobe budget, candidate caps, and rerank policy. Those knobs used
//! to be frozen into the index at build time; here they are call-time
//! arguments carried by one [`Query`] value, so a single built index serves
//! many scenarios (cheap signature-only scans, budgeted exact re-ranks,
//! aggressive multiprobe for recall-critical traffic) without rebuilding.
//!
//! * [`Query`] — the request: a tensor plus plain-data [`QueryOpts`]
//!   (`k`, per-query `probes` override, candidate cap, [`RerankPolicy`],
//!   exact-fallback and dedup toggles). The opts are JSON round-trippable,
//!   which is what the coordinator protocol serializes.
//! * [`SearchResponse`] — the hits plus per-query [`SearchStats`]
//!   (candidates generated/examined, probes used, tables hit, re-rank
//!   count) so callers can see what a query actually cost.
//! * [`Searcher`] — `search(&Query)` / `search_batch(&[Query])`,
//!   implemented by [`crate::index::LshIndex`],
//!   [`crate::index::ShardedLshIndex`], and
//!   [`crate::coordinator::Coordinator`]. Batches route through the flat
//!   `ProjectionMatrix`/`CodeMatrix` SoA path with a reused
//!   [`crate::index::HashScratch`].
//!
//! The pre-0.3 per-item `search`/`search_batch`/`shard_search` wrappers
//! were removed once this API became the only caller: a default `Query`
//! is bit-identical to what they did (`tests/query_api.rs`), and the
//! `Searcher` trait methods now resolve directly on the concrete index
//! types as well as through `&dyn Searcher`.
//!
//! Tie-breaking: hits are ordered best-first (ascending distance,
//! descending similarity or collision count) with ties broken by ascending
//! item id, so results are fully deterministic even under duplicate scores.

use crate::error::{Error, Result};
use crate::index::SearchResult;
use crate::tensor::AnyTensor;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// How candidates are scored before the top-k cut.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RerankPolicy {
    /// Exactly score every examined candidate (one inner product each) —
    /// the classical LSH re-rank and the default.
    Exact,
    /// No inner products at all: hits are ranked by their bucket collision
    /// count (how many probed buckets contained the item), best-first
    /// descending. `score` holds the collision count for both metrics.
    SignatureOnly,
    /// Exactly score at most `n` candidates, taken most-collisions-first
    /// (ties keep candidate-generation order); the rest are dropped. On the
    /// sharded fan-out the budget applies per probing unit (per shard).
    Budgeted(usize),
}

impl RerankPolicy {
    /// Parse a policy as it appears on the CLI / in JSON:
    /// `exact`, `signature`, or `budget:N`.
    pub fn parse(s: &str) -> Result<RerankPolicy> {
        match s {
            "exact" => Ok(RerankPolicy::Exact),
            "signature" | "signature_only" | "sigs" => Ok(RerankPolicy::SignatureOnly),
            other => {
                if let Some(n) = other
                    .strip_prefix("budget:")
                    .or_else(|| other.strip_prefix("budgeted:"))
                {
                    let n: usize = n.parse().map_err(|e| {
                        Error::InvalidParameter(format!("rerank budget '{n}': {e}"))
                    })?;
                    return Ok(RerankPolicy::Budgeted(n));
                }
                Err(Error::InvalidParameter(format!(
                    "unknown rerank policy '{other}' (expected one of: exact, signature, \
                     budget:N)"
                )))
            }
        }
    }

    /// Canonical name; `parse(name())` is the identity.
    pub fn name(&self) -> String {
        match self {
            RerankPolicy::Exact => "exact".into(),
            RerankPolicy::SignatureOnly => "signature".into(),
            RerankPolicy::Budgeted(n) => format!("budget:{n}"),
        }
    }
}

/// Plain-data per-query knobs — everything about a query except the tensor.
/// JSON round-trippable (this is the part the coordinator protocol
/// serializes; the tensor payload travels in its native format).
#[derive(Clone, Debug, PartialEq)]
pub struct QueryOpts {
    /// Neighbors to return.
    pub k: usize,
    /// Per-query multiprobe override: `None` uses the index's build-time
    /// default (`LshSpec::probes`), `Some(p)` probes `p` extra buckets per
    /// table for this query only.
    pub probes: Option<usize>,
    /// Cap on candidates examined (applied after generation, before
    /// re-ranking; generation order is kept). On the sharded fan-out the
    /// cap applies per probing unit (per shard). `None` = unbounded.
    pub max_candidates: Option<usize>,
    /// How candidates are scored.
    pub rerank: RerankPolicy,
    /// When probing examines no candidate at all, fall back to an exact
    /// linear scan instead of returning an empty response.
    pub exact_fallback: bool,
    /// Deduplicate candidates across tables/probes (the default). Turning
    /// this off skips the dedup pass; duplicated candidates are then
    /// scored once per occurrence and may repeat in the hits — a
    /// diagnostics/throughput knob, not for production ranking.
    pub dedup: bool,
}

impl QueryOpts {
    /// Defaults that make a query bit-identical to the legacy `search`
    /// surface: index-default probes, no cap, exact re-rank, no fallback,
    /// dedup on.
    pub fn top_k(k: usize) -> QueryOpts {
        QueryOpts {
            k,
            probes: None,
            max_candidates: None,
            rerank: RerankPolicy::Exact,
            exact_fallback: false,
            dedup: true,
        }
    }

    // -- fluent setters ----------------------------------------------------

    pub fn with_probes(mut self, probes: usize) -> QueryOpts {
        self.probes = Some(probes);
        self
    }

    pub fn with_max_candidates(mut self, cap: usize) -> QueryOpts {
        self.max_candidates = Some(cap);
        self
    }

    pub fn with_rerank(mut self, rerank: RerankPolicy) -> QueryOpts {
        self.rerank = rerank;
        self
    }

    pub fn with_exact_fallback(mut self, on: bool) -> QueryOpts {
        self.exact_fallback = on;
        self
    }

    pub fn with_dedup(mut self, on: bool) -> QueryOpts {
        self.dedup = on;
        self
    }

    // -- JSON --------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let opt = |v: Option<usize>| match v {
            Some(n) => Json::Num(n as f64),
            None => Json::Null,
        };
        let mut m = BTreeMap::new();
        m.insert("k".to_string(), Json::Num(self.k as f64));
        m.insert("probes".to_string(), opt(self.probes));
        m.insert("max_candidates".to_string(), opt(self.max_candidates));
        m.insert("rerank".to_string(), Json::Str(self.rerank.name()));
        m.insert("exact_fallback".to_string(), Json::Bool(self.exact_fallback));
        m.insert("dedup".to_string(), Json::Bool(self.dedup));
        Json::Obj(m)
    }

    /// Parse opts; `probes`/`max_candidates` accept `null` or absence for
    /// "unset", booleans and `rerank` may be omitted (defaults apply).
    pub fn from_json(v: &Json) -> Result<QueryOpts> {
        let obj = v.as_obj()?;
        for key in obj.keys() {
            if !["k", "probes", "max_candidates", "rerank", "exact_fallback", "dedup"]
                .contains(&key.as_str())
            {
                return Err(Error::Json(format!("unknown query key '{key}'")));
            }
        }
        let opt = |key: &str| -> Result<Option<usize>> {
            match obj.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(other) => Ok(Some(other.as_usize()?)),
            }
        };
        let flag = |key: &str, default: bool| -> Result<bool> {
            match obj.get(key) {
                None => Ok(default),
                Some(Json::Bool(b)) => Ok(*b),
                Some(other) => {
                    Err(Error::Json(format!("expected bool for '{key}', got {other:?}")))
                }
            }
        };
        Ok(QueryOpts {
            k: v.get("k")?.as_usize()?,
            probes: opt("probes")?,
            max_candidates: opt("max_candidates")?,
            rerank: match obj.get("rerank") {
                None => RerankPolicy::Exact,
                Some(r) => RerankPolicy::parse(r.as_str()?)?,
            },
            exact_fallback: flag("exact_fallback", false)?,
            dedup: flag("dedup", true)?,
        })
    }
}

/// A k-NN request: the query tensor plus its plain-data [`QueryOpts`].
#[derive(Clone, Debug)]
pub struct Query {
    pub tensor: AnyTensor,
    pub opts: QueryOpts,
}

impl Query {
    /// A default query — bit-identical to the legacy `search(tensor, k)`.
    pub fn new(tensor: AnyTensor, k: usize) -> Query {
        Query { tensor, opts: QueryOpts::top_k(k) }
    }

    pub fn with_opts(tensor: AnyTensor, opts: QueryOpts) -> Query {
        Query { tensor, opts }
    }

    // -- fluent setters (delegating to the opts) ---------------------------

    pub fn probes(mut self, probes: usize) -> Query {
        self.opts.probes = Some(probes);
        self
    }

    pub fn max_candidates(mut self, cap: usize) -> Query {
        self.opts.max_candidates = Some(cap);
        self
    }

    pub fn rerank(mut self, rerank: RerankPolicy) -> Query {
        self.opts.rerank = rerank;
        self
    }

    pub fn exact_fallback(mut self, on: bool) -> Query {
        self.opts.exact_fallback = on;
        self
    }

    pub fn dedup(mut self, on: bool) -> Query {
        self.opts.dedup = on;
        self
    }
}

/// What one query actually cost. Stats from shard/worker partials merge
/// with [`SearchStats::merge`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SearchStats {
    /// Candidates produced by probing, before any cap (deduplicated when
    /// `QueryOpts::dedup`; with multiplicity otherwise).
    pub candidates_generated: usize,
    /// Candidates kept after `max_candidates` — the set handed to the
    /// re-rank policy.
    pub candidates_examined: usize,
    /// Extra multiprobe signatures used beyond the exact bucket, summed
    /// over tables (the per-query probe budget actually spent).
    pub probes_used: usize,
    /// Tables whose probed buckets yielded at least one candidate, within
    /// one probing unit; merged across shards as the max over units (a
    /// lower bound on the union).
    pub tables_hit: usize,
    /// Candidates scored with a full inner product (0 under
    /// [`RerankPolicy::SignatureOnly`]; includes the exact-fallback scan).
    pub reranked: usize,
    /// True when the exact-fallback linear scan produced the hits.
    pub exact_fallback: bool,
}

impl SearchStats {
    /// Fold another probing unit's stats into this one: counts sum,
    /// `probes_used`/`tables_hit` take the max (each unit reports the same
    /// probe budget / overlapping tables), fallback ORs.
    pub fn merge(&mut self, other: &SearchStats) {
        self.candidates_generated += other.candidates_generated;
        self.candidates_examined += other.candidates_examined;
        self.reranked += other.reranked;
        self.probes_used = self.probes_used.max(other.probes_used);
        self.tables_hit = self.tables_hit.max(other.tables_hit);
        self.exact_fallback |= other.exact_fallback;
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert(
            "candidates_generated".to_string(),
            Json::Num(self.candidates_generated as f64),
        );
        m.insert(
            "candidates_examined".to_string(),
            Json::Num(self.candidates_examined as f64),
        );
        m.insert("probes_used".to_string(), Json::Num(self.probes_used as f64));
        m.insert("tables_hit".to_string(), Json::Num(self.tables_hit as f64));
        m.insert("reranked".to_string(), Json::Num(self.reranked as f64));
        m.insert("exact_fallback".to_string(), Json::Bool(self.exact_fallback));
        Json::Obj(m)
    }

    /// Inverse of [`SearchStats::to_json`] — the wire protocol ships stats
    /// as JSON and must reproduce them exactly. Unknown keys are rejected.
    pub fn from_json(v: &Json) -> Result<SearchStats> {
        let obj = v.as_obj()?;
        for key in obj.keys() {
            if ![
                "candidates_generated",
                "candidates_examined",
                "probes_used",
                "tables_hit",
                "reranked",
                "exact_fallback",
            ]
            .contains(&key.as_str())
            {
                return Err(Error::Json(format!("unknown stats key '{key}'")));
            }
        }
        Ok(SearchStats {
            candidates_generated: v.get("candidates_generated")?.as_usize()?,
            candidates_examined: v.get("candidates_examined")?.as_usize()?,
            probes_used: v.get("probes_used")?.as_usize()?,
            tables_hit: v.get("tables_hit")?.as_usize()?,
            reranked: v.get("reranked")?.as_usize()?,
            exact_fallback: match v.get("exact_fallback")? {
                Json::Bool(b) => *b,
                other => {
                    return Err(Error::Json(format!(
                        "expected bool for 'exact_fallback', got {other:?}"
                    )))
                }
            },
        })
    }
}

/// Response to a [`Query`]: ranked hits plus what they cost.
#[derive(Clone, Debug, PartialEq)]
pub struct SearchResponse {
    /// Best-first hits (ties broken by ascending id — fully deterministic).
    pub hits: Vec<SearchResult>,
    pub stats: SearchStats,
}

/// One search surface across the serving stack: [`crate::index::LshIndex`]
/// (single-shard reference), [`crate::index::ShardedLshIndex`] (serving
/// structure), and [`crate::coordinator::Coordinator`] (scatter-gather
/// pipeline) all answer the same [`Query`].
///
/// `search_batch` implementations route through the flat SoA hash path
/// with a reused [`crate::index::HashScratch`] where the layer supports it;
/// the default just loops.
pub trait Searcher {
    fn search(&self, q: &Query) -> Result<SearchResponse>;

    fn search_batch(&self, qs: &[Query]) -> Result<Vec<SearchResponse>> {
        qs.iter().map(|q| self.search(q)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DenseTensor;

    #[test]
    fn rerank_policy_parse_name_roundtrip() {
        for p in [
            RerankPolicy::Exact,
            RerankPolicy::SignatureOnly,
            RerankPolicy::Budgeted(0),
            RerankPolicy::Budgeted(128),
        ] {
            assert_eq!(RerankPolicy::parse(&p.name()).unwrap(), p);
        }
        assert_eq!(
            RerankPolicy::parse("budgeted:7").unwrap(),
            RerankPolicy::Budgeted(7)
        );
        assert!(RerankPolicy::parse("nope").is_err());
        assert!(RerankPolicy::parse("budget:x").is_err());
    }

    #[test]
    fn query_opts_json_roundtrip() {
        let opts = QueryOpts::top_k(7)
            .with_probes(3)
            .with_max_candidates(100)
            .with_rerank(RerankPolicy::Budgeted(40))
            .with_exact_fallback(true)
            .with_dedup(false);
        let back = QueryOpts::from_json(&opts.to_json()).unwrap();
        assert_eq!(back, opts);
        // Defaults round-trip too (probes/max_candidates as null).
        let dflt = QueryOpts::top_k(10);
        assert_eq!(QueryOpts::from_json(&dflt.to_json()).unwrap(), dflt);
        // Minimal document: only k, everything else defaulted.
        let min = QueryOpts::from_json(&crate::util::json::parse(r#"{"k": 5}"#).unwrap())
            .unwrap();
        assert_eq!(min, QueryOpts::top_k(5));
        // Unknown keys are rejected, not silently defaulted.
        let typo = crate::util::json::parse(r#"{"k": 5, "probess": 2}"#).unwrap();
        assert!(QueryOpts::from_json(&typo).is_err());
    }

    #[test]
    fn query_builder_sets_opts() {
        let t = AnyTensor::Dense(DenseTensor::zeros(&[2, 2]));
        let q = Query::new(t, 5)
            .probes(2)
            .max_candidates(50)
            .rerank(RerankPolicy::SignatureOnly)
            .exact_fallback(true)
            .dedup(false);
        assert_eq!(q.opts.k, 5);
        assert_eq!(q.opts.probes, Some(2));
        assert_eq!(q.opts.max_candidates, Some(50));
        assert_eq!(q.opts.rerank, RerankPolicy::SignatureOnly);
        assert!(q.opts.exact_fallback);
        assert!(!q.opts.dedup);
    }

    #[test]
    fn stats_merge_sums_counts_and_maxes_shared_fields() {
        let mut a = SearchStats {
            candidates_generated: 10,
            candidates_examined: 8,
            probes_used: 4,
            tables_hit: 3,
            reranked: 8,
            exact_fallback: false,
        };
        let b = SearchStats {
            candidates_generated: 5,
            candidates_examined: 5,
            probes_used: 4,
            tables_hit: 5,
            reranked: 2,
            exact_fallback: true,
        };
        a.merge(&b);
        assert_eq!(a.candidates_generated, 15);
        assert_eq!(a.candidates_examined, 13);
        assert_eq!(a.reranked, 10);
        assert_eq!(a.probes_used, 4);
        assert_eq!(a.tables_hit, 5);
        assert!(a.exact_fallback);
    }

    #[test]
    fn stats_json_roundtrip() {
        let stats = SearchStats {
            candidates_generated: 123,
            candidates_examined: 45,
            probes_used: 6,
            tables_hit: 7,
            reranked: 45,
            exact_fallback: true,
        };
        assert_eq!(SearchStats::from_json(&stats.to_json()).unwrap(), stats);
        assert_eq!(
            SearchStats::from_json(&SearchStats::default().to_json()).unwrap(),
            SearchStats::default()
        );
        // Unknown keys are rejected, not silently ignored.
        let typo = crate::util::json::parse(
            r#"{"candidates_generated": 1, "candidates_examined": 1, "probes_used": 0,
                "tables_hit": 1, "reranked": 1, "exact_fallback": false, "extra": 0}"#,
        )
        .unwrap();
        assert!(SearchStats::from_json(&typo).is_err());
    }
}
