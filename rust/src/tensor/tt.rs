//! Tensor-train format tensor (Definition 5) and TT-Rademacher generation
//! (Definition 7).

// Not the precision-audited hash path: tensor values are stored f32 by design (see README §Layout).
#![allow(clippy::cast_possible_truncation)]

use super::dense::DenseTensor;
use crate::error::{Error, Result};
use crate::rng::{Rng, Sampler};

/// A TT core G ∈ R^{r0 × d × r1}, row-major in (r0, d, r1).
#[derive(Clone, Debug, PartialEq)]
pub struct TtCore {
    pub r0: usize,
    pub d: usize,
    pub r1: usize,
    pub data: Vec<f32>,
}

impl TtCore {
    pub fn zeros(r0: usize, d: usize, r1: usize) -> Self {
        TtCore { r0, d, r1, data: vec![0.0; r0 * d * r1] }
    }

    #[inline]
    pub fn get(&self, a: usize, i: usize, b: usize) -> f32 {
        self.data[(a * self.d + i) * self.r1 + b]
    }

    #[inline]
    pub fn set(&mut self, a: usize, i: usize, b: usize, v: f32) {
        self.data[(a * self.d + i) * self.r1 + b] = v;
    }

    /// The r0×r1 slice G[:, i, :] flattened row-major (copied).
    pub fn slice(&self, i: usize) -> Vec<f32> {
        let mut out = vec![0.0; self.r0 * self.r1];
        for a in 0..self.r0 {
            for b in 0..self.r1 {
                out[a * self.r1 + b] = self.get(a, i, b);
            }
        }
        out
    }
}

/// Tensor in TT decomposition format:
/// `X[i₁..i_N] = scale · G₁[:,i₁,:] G₂[:,i₂,:] ⋯ G_N[:,i_N,:]`.
///
/// `scale` carries the `1/√(R^{N−1})` of TT-Rademacher projection tensors.
#[derive(Clone, Debug)]
pub struct TtTensor {
    pub cores: Vec<TtCore>,
    pub scale: f32,
}

impl TtTensor {
    /// Construct, validating the bond-rank chain (r_0 = r_N = 1, contiguous).
    pub fn new(cores: Vec<TtCore>) -> Result<Self> {
        if cores.is_empty() {
            return Err(Error::InvalidParameter("TT tensor needs ≥1 core".into()));
        }
        if cores[0].r0 != 1 || cores[cores.len() - 1].r1 != 1 {
            return Err(Error::ShapeMismatch("TT boundary ranks must be 1".into()));
        }
        for w in cores.windows(2) {
            if w[0].r1 != w[1].r0 {
                return Err(Error::ShapeMismatch(format!(
                    "TT bond mismatch: {} vs {}",
                    w[0].r1, w[1].r0
                )));
            }
        }
        Ok(TtTensor { cores, scale: 1.0 })
    }

    /// Bond shapes for order-n, uniform internal rank r.
    pub fn uniform_ranks(n: usize, r: usize) -> Vec<(usize, usize)> {
        (0..n)
            .map(|i| (if i == 0 { 1 } else { r }, if i == n - 1 { 1 } else { r }))
            .collect()
    }

    /// IID Gaussian cores — generic random TT tensor (workloads).
    pub fn random_gaussian(rng: &mut Rng, dims: &[usize], rank: usize) -> Self {
        let cores = Self::uniform_ranks(dims.len(), rank)
            .into_iter()
            .zip(dims)
            .map(|((r0, r1), &d)| {
                let mut c = TtCore::zeros(r0, d, r1);
                rng.fill_normal_f32(&mut c.data);
                c
            })
            .collect();
        TtTensor { cores, scale: 1.0 }
    }

    /// TT-distributed random tensor with entries from `sampler` and the
    /// 1/√(R^{N−1}) normalization of Definition 7 (`TT_Rad(R)` / `TT_N(R)`).
    pub fn random_projection(
        rng: &mut Rng,
        dims: &[usize],
        rank: usize,
        sampler: &dyn Sampler,
    ) -> Self {
        let n = dims.len();
        let cores: Vec<TtCore> = Self::uniform_ranks(n, rank)
            .into_iter()
            .zip(dims)
            .map(|((r0, r1), &d)| {
                let mut c = TtCore::zeros(r0, d, r1);
                sampler.fill(rng, &mut c.data);
                c
            })
            .collect();
        let scale = 1.0 / (rank as f32).powi(n as i32 - 1).sqrt();
        TtTensor { cores, scale }
    }

    /// Tensor order N.
    pub fn order(&self) -> usize {
        self.cores.len()
    }

    /// Mode dimensions.
    pub fn dims(&self) -> Vec<usize> {
        self.cores.iter().map(|c| c.d).collect()
    }

    /// Maximum bond rank (the TT rank R of Definition 5 for uniform chains).
    pub fn max_rank(&self) -> usize {
        self.cores.iter().map(|c| c.r0.max(c.r1)).max().unwrap_or(1)
    }

    /// Stored parameter count (`O(NdR²)` — the Tables 1–2 space column).
    pub fn param_count(&self) -> usize {
        self.cores.iter().map(|c| c.data.len()).sum()
    }

    /// Materialize to dense via sequential core products (reference path).
    pub fn materialize(&self) -> DenseTensor {
        // acc: (prod_dims_so_far, r_cur), row-major.
        let mut acc: Vec<f64> = vec![1.0];
        let mut lead = 1usize;
        let mut bond = 1usize;
        for core in &self.cores {
            let new_bond = core.r1;
            let mut next = vec![0.0f64; lead * core.d * new_bond];
            for l in 0..lead {
                for a in 0..bond {
                    let av = acc[l * bond + a];
                    if av == 0.0 {
                        continue;
                    }
                    for i in 0..core.d {
                        for b in 0..new_bond {
                            next[(l * core.d + i) * new_bond + b] +=
                                av * core.get(a, i, b) as f64;
                        }
                    }
                }
            }
            lead *= core.d;
            bond = new_bond;
            acc = next;
        }
        let dims = self.dims();
        let data = acc
            .into_iter()
            .map(|v| (v * self.scale as f64) as f32)
            .collect();
        DenseTensor::from_data(&dims, data).expect("tt materialize shape")
    }

    /// Frobenius norm without materializing (self inner product via the
    /// transfer-matrix sweep — O(NdR⁴) worst case, fine for bookkeeping).
    pub fn frob_norm(&self) -> f64 {
        super::inner::tt_tt(self, self).max(0.0).sqrt()
    }

    /// TT sum `alpha·self + beta·other` via block-diagonal cores: bond ranks
    /// add (the standard TT addition; both scales fold into the first core).
    pub fn add_scaled(&self, alpha: f32, other: &TtTensor, beta: f32) -> Result<TtTensor> {
        super::check_same_shape(&self.dims(), &other.dims())?;
        let n = self.order();
        let mut cores = Vec::with_capacity(n);
        for ax in 0..n {
            let (a, b) = (&self.cores[ax], &other.cores[ax]);
            let (sa, sb) = if ax == 0 {
                (alpha * self.scale, beta * other.scale)
            } else {
                (1.0, 1.0)
            };
            let (r0, r1) = if n == 1 {
                (1, 1)
            } else if ax == 0 {
                (1, a.r1 + b.r1)
            } else if ax == n - 1 {
                (a.r0 + b.r0, 1)
            } else {
                (a.r0 + b.r0, a.r1 + b.r1)
            };
            let mut core = TtCore::zeros(r0, a.d, r1);
            if n == 1 {
                // Order-1: plain vector addition.
                for i in 0..a.d {
                    core.set(0, i, 0, sa * a.get(0, i, 0) + sb * b.get(0, i, 0));
                }
            } else {
                // A block at (0..a.r0, 0..a.r1); B block offset by A's ranks
                // (collapsed on boundary cores).
                let (a_off0, a_off1) = (0usize, 0usize);
                let b_off0 = if ax == 0 { 0 } else { a.r0 };
                let b_off1 = if ax == n - 1 { 0 } else { a.r1 };
                for i in 0..a.d {
                    for p in 0..a.r0 {
                        for q in 0..a.r1 {
                            core.set(a_off0 + p, i, a_off1 + q, sa * a.get(p, i, q));
                        }
                    }
                    for p in 0..b.r0 {
                        for q in 0..b.r1 {
                            let cur = core.get(b_off0 + p, i, b_off1 + q);
                            core.set(b_off0 + p, i, b_off1 + q, cur + sb * b.get(p, i, q));
                        }
                    }
                }
            }
            cores.push(core);
        }
        TtTensor::new(cores)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::RademacherSampler;

    #[test]
    fn materialize_order2_is_matmul() {
        // TT of a matrix: X = G1[0,:,:] @ G2[:,:,0]
        let mut g1 = TtCore::zeros(1, 2, 2);
        let mut g2 = TtCore::zeros(2, 3, 1);
        // G1[0, i, a] = i + a + 1
        for i in 0..2 {
            for a in 0..2 {
                g1.set(0, i, a, (i + a + 1) as f32);
            }
        }
        // G2[a, j, 0] = a*10 + j
        for a in 0..2 {
            for j in 0..3 {
                g2.set(a, j, 0, (a * 10 + j) as f32);
            }
        }
        let t = TtTensor::new(vec![g1, g2]).unwrap();
        let d = t.materialize();
        // X[i,j] = sum_a (i+a+1)(10a + j)
        for i in 0..2 {
            for j in 0..3 {
                let expect: f32 = (0..2)
                    .map(|a| ((i + a + 1) * (10 * a + j)) as f32)
                    .sum();
                assert_eq!(d.get(&[i, j]), expect);
            }
        }
    }

    #[test]
    fn new_validates_bonds() {
        let g1 = TtCore::zeros(1, 2, 3);
        let g2 = TtCore::zeros(2, 2, 1); // mismatch 3 vs 2
        assert!(TtTensor::new(vec![g1, g2]).is_err());
        let g1 = TtCore::zeros(2, 2, 2);
        let g2 = TtCore::zeros(2, 2, 1); // r0 != 1
        assert!(TtTensor::new(vec![g1, g2]).is_err());
    }

    #[test]
    fn frob_norm_matches_materialized() {
        let mut rng = Rng::new(20);
        let t = TtTensor::random_gaussian(&mut rng, &[3, 4, 5], 3);
        assert!((t.frob_norm() - t.materialize().frob_norm()).abs() < 1e-3);
    }

    #[test]
    fn projection_scale_is_pow() {
        let mut rng = Rng::new(21);
        let t = TtTensor::random_projection(&mut rng, &[3, 3, 3], 4, &RademacherSampler);
        // 1/sqrt(4^2) = 0.25
        assert!((t.scale - 0.25).abs() < 1e-7);
        assert!(t.cores.iter().all(|c| c.data.iter().all(|&v| v == 1.0 || v == -1.0)));
    }

    #[test]
    fn add_scaled_matches_dense() {
        let mut rng = Rng::new(23);
        for dims in [vec![5usize], vec![4, 5], vec![3, 4, 2], vec![2, 3, 2, 2]] {
            let mut a = TtTensor::random_gaussian(&mut rng, &dims, 2);
            a.scale = 0.5;
            let b = TtTensor::random_gaussian(&mut rng, &dims, 3);
            let s = a.add_scaled(2.0, &b, -0.25).unwrap();
            let mut expect = a.materialize();
            expect.scale(2.0);
            expect.axpy(-0.25, &b.materialize()).unwrap();
            let got = s.materialize();
            for (x, y) in got.data.iter().zip(&expect.data) {
                assert!((x - y).abs() < 1e-4, "dims {dims:?}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn add_scaled_bond_ranks_add() {
        let mut rng = Rng::new(24);
        let a = TtTensor::random_gaussian(&mut rng, &[4, 4, 4], 2);
        let b = TtTensor::random_gaussian(&mut rng, &[4, 4, 4], 3);
        let s = a.add_scaled(1.0, &b, 1.0).unwrap();
        assert_eq!(s.max_rank(), 5);
    }

    #[test]
    fn param_count_is_ndr2() {
        let mut rng = Rng::new(22);
        let t = TtTensor::random_gaussian(&mut rng, &[5, 5, 5, 5], 3);
        // 1*5*3 + 3*5*3 + 3*5*3 + 3*5*1 = 15 + 45 + 45 + 15
        assert_eq!(t.param_count(), 120);
    }
}
