//! Tensor formats and the inner products that drive every hash family.
//!
//! Three concrete formats:
//! * [`DenseTensor`] — row-major N-d array (the naive baseline's format).
//! * [`CpTensor`] — CP/PARAFAC format (Definition 4): `N` factor matrices
//!   `A⁽ⁿ⁾ ∈ R^{dₙ×R}`, `X = Σ_r a_r⁽¹⁾∘…∘a_r⁽ᴺ⁾`, `O(NdR)` space.
//! * [`TtTensor`] — tensor-train format (Definition 5): `N` cores
//!   `G⁽ⁿ⁾ ∈ R^{rₙ₋₁×dₙ×rₙ}`, `O(NdR²)` space.
//!
//! [`inner`] implements every inner-product pairing at the complexity the
//! paper's Tables 1–2 claim; [`AnyTensor`] dispatches to the right one.

mod cp;
mod dense;
pub mod inner;
mod tt;

pub use cp::{CpTensor, Factor};
pub use dense::DenseTensor;
pub use tt::{TtCore, TtTensor};

use crate::error::{Error, Result};

/// Row-major strides for a shape.
pub fn strides(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * shape[i + 1];
    }
    s
}

/// Total number of elements.
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Validate that two shapes match.
pub fn check_same_shape(a: &[usize], b: &[usize]) -> Result<()> {
    if a != b {
        return Err(Error::ShapeMismatch(format!("{a:?} vs {b:?}")));
    }
    Ok(())
}

/// A tensor in any supported format. The hash families and the index accept
/// `AnyTensor` so that corpora can mix formats (the paper's complexity table
/// is indexed by input format).
#[derive(Clone, Debug)]
pub enum AnyTensor {
    Dense(DenseTensor),
    Cp(CpTensor),
    Tt(TtTensor),
}

impl AnyTensor {
    /// Mode dimensions.
    pub fn dims(&self) -> Vec<usize> {
        match self {
            AnyTensor::Dense(t) => t.shape.clone(),
            AnyTensor::Cp(t) => t.dims(),
            AnyTensor::Tt(t) => t.dims(),
        }
    }

    /// Tensor order N.
    pub fn order(&self) -> usize {
        self.dims().len()
    }

    /// Format name for reports.
    pub fn format(&self) -> &'static str {
        match self {
            AnyTensor::Dense(_) => "dense",
            AnyTensor::Cp(_) => "cp",
            AnyTensor::Tt(_) => "tt",
        }
    }

    /// Representation rank (R̂): 0 for dense, CP rank, or max TT bond rank.
    pub fn rank(&self) -> usize {
        match self {
            AnyTensor::Dense(_) => 0,
            AnyTensor::Cp(t) => t.rank(),
            AnyTensor::Tt(t) => t.max_rank(),
        }
    }

    /// Materialize to a dense tensor (O(d^N) — test/reference path only).
    pub fn materialize(&self) -> DenseTensor {
        match self {
            AnyTensor::Dense(t) => t.clone(),
            AnyTensor::Cp(t) => t.materialize(),
            AnyTensor::Tt(t) => t.materialize(),
        }
    }

    /// Frobenius norm, computed format-natively (no materialization).
    pub fn frob_norm(&self) -> f64 {
        match self {
            AnyTensor::Dense(t) => t.frob_norm(),
            AnyTensor::Cp(t) => t.frob_norm(),
            AnyTensor::Tt(t) => t.frob_norm(),
        }
    }

    /// Mode dimension along axis `ax` without allocating.
    #[inline]
    pub fn dim(&self, ax: usize) -> usize {
        match self {
            AnyTensor::Dense(t) => t.shape[ax],
            AnyTensor::Cp(t) => t.factors[ax].d,
            AnyTensor::Tt(t) => t.cores[ax].d,
        }
    }

    /// Allocation-free shape comparison (the re-ranking hot path calls
    /// [`AnyTensor::inner`] per candidate; building `dims()` Vecs there
    /// dominated the profile — §Perf).
    #[inline]
    pub fn same_dims(&self, other: &AnyTensor) -> bool {
        let n = match self {
            AnyTensor::Dense(t) => t.shape.len(),
            AnyTensor::Cp(t) => t.factors.len(),
            AnyTensor::Tt(t) => t.cores.len(),
        };
        let m = match other {
            AnyTensor::Dense(t) => t.shape.len(),
            AnyTensor::Cp(t) => t.factors.len(),
            AnyTensor::Tt(t) => t.cores.len(),
        };
        n == m && (0..n).all(|ax| self.dim(ax) == other.dim(ax))
    }

    /// Inner product with another tensor, dispatching to the cheapest
    /// pairing (Tables 1–2 complexities; see [`inner`]).
    pub fn inner(&self, other: &AnyTensor) -> Result<f64> {
        use AnyTensor::*;
        if !self.same_dims(other) {
            return Err(Error::ShapeMismatch(format!(
                "{:?} vs {:?}",
                self.dims(),
                other.dims()
            )));
        }
        Ok(match (self, other) {
            (Dense(a), Dense(b)) => inner::dense_dense(a, b),
            (Dense(a), Cp(b)) | (Cp(b), Dense(a)) => inner::dense_cp(a, b),
            (Dense(a), Tt(b)) | (Tt(b), Dense(a)) => inner::dense_tt(a, b),
            (Cp(a), Cp(b)) => inner::cp_cp(a, b),
            (Cp(a), Tt(b)) | (Tt(b), Cp(a)) => inner::cp_tt(a, b),
            (Tt(a), Tt(b)) => inner::tt_tt(a, b),
        })
    }

    /// Euclidean (Frobenius) distance ‖X − Y‖_F (Eq. 3.5), format-natively.
    pub fn distance(&self, other: &AnyTensor) -> Result<f64> {
        let d2 = self.frob_norm().powi(2) - 2.0 * self.inner(other)?
            + other.frob_norm().powi(2);
        Ok(d2.max(0.0).sqrt())
    }

    /// Cosine similarity (Eq. 3.6), format-natively.
    pub fn cosine(&self, other: &AnyTensor) -> Result<f64> {
        let denom = self.frob_norm() * other.frob_norm();
        if denom == 0.0 {
            return Err(Error::Numerical("cosine of zero tensor".into()));
        }
        Ok((self.inner(other)? / denom).clamp(-1.0, 1.0))
    }

    /// Parameter count of the representation (the space column of Tables 1–2).
    pub fn param_count(&self) -> usize {
        match self {
            AnyTensor::Dense(t) => t.data.len(),
            AnyTensor::Cp(t) => t.param_count(),
            AnyTensor::Tt(t) => t.param_count(),
        }
    }
}

impl From<DenseTensor> for AnyTensor {
    fn from(t: DenseTensor) -> Self {
        AnyTensor::Dense(t)
    }
}
impl From<CpTensor> for AnyTensor {
    fn from(t: CpTensor) -> Self {
        AnyTensor::Cp(t)
    }
}
impl From<TtTensor> for AnyTensor {
    fn from(t: TtTensor) -> Self {
        AnyTensor::Tt(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn strides_row_major() {
        assert_eq!(strides(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides(&[5]), vec![1]);
    }

    #[test]
    fn any_tensor_cross_format_inner_agrees_with_dense() {
        let mut rng = Rng::new(77);
        let dims = [4usize, 3, 5];
        let cp = CpTensor::random_gaussian(&mut rng, &dims, 3);
        let tt = TtTensor::random_gaussian(&mut rng, &dims, 2);
        let de = DenseTensor::random_gaussian(&mut rng, &dims);
        let tensors = [
            AnyTensor::Cp(cp),
            AnyTensor::Tt(tt),
            AnyTensor::Dense(de),
        ];
        for a in &tensors {
            for b in &tensors {
                let fast = a.inner(b).unwrap();
                let slow = inner::dense_dense(&a.materialize(), &b.materialize());
                assert!(
                    (fast - slow).abs() < 1e-3 * (1.0 + slow.abs()),
                    "{} vs {}: {fast} != {slow}",
                    a.format(),
                    b.format()
                );
            }
        }
    }

    #[test]
    fn distance_and_cosine_consistency() {
        let mut rng = Rng::new(5);
        let dims = [3usize, 4, 2];
        let a = AnyTensor::Cp(CpTensor::random_gaussian(&mut rng, &dims, 2));
        let b = AnyTensor::Tt(TtTensor::random_gaussian(&mut rng, &dims, 2));
        let (da, db) = (a.materialize(), b.materialize());
        let mut d2 = 0.0;
        for (x, y) in da.data.iter().zip(&db.data) {
            d2 += (*x as f64 - *y as f64).powi(2);
        }
        assert!((a.distance(&b).unwrap() - d2.sqrt()).abs() < 1e-3);
        let cos = a.cosine(&b).unwrap();
        assert!((-1.0..=1.0).contains(&cos));
    }

    #[test]
    fn shape_mismatch_is_error() {
        let mut rng = Rng::new(6);
        let a = AnyTensor::Dense(DenseTensor::random_gaussian(&mut rng, &[2, 2]));
        let b = AnyTensor::Dense(DenseTensor::random_gaussian(&mut rng, &[2, 3]));
        assert!(a.inner(&b).is_err());
    }
}
