//! CP/PARAFAC format tensor (Definition 4) and CP-Rademacher generation
//! (Definition 6).

// Not the precision-audited hash path: tensor values are stored f32 by design (see README §Layout).
#![allow(clippy::cast_possible_truncation)]

use super::dense::DenseTensor;
use super::tt::{TtCore, TtTensor};
use crate::error::{Error, Result};
use crate::rng::{Rng, Sampler};

/// A d×R factor matrix, row-major (row = mode index, column = rank).
#[derive(Clone, Debug, PartialEq)]
pub struct Factor {
    pub d: usize,
    pub r: usize,
    pub data: Vec<f32>,
}

impl Factor {
    pub fn zeros(d: usize, r: usize) -> Self {
        Factor { d, r, data: vec![0.0; d * r] }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.r + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.r + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.r..(i + 1) * self.r]
    }

    /// Column `j` as a fresh vector.
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.d).map(|i| self.get(i, j)).collect()
    }
}

/// Tensor in CP decomposition format: `X = scale · Σ_r a_r⁽¹⁾ ∘ … ∘ a_r⁽ᴺ⁾`.
///
/// The extra `scale` carries normalizations like the `1/√R` of
/// CP-Rademacher projection tensors without touching the factors.
#[derive(Clone, Debug)]
pub struct CpTensor {
    pub factors: Vec<Factor>,
    pub scale: f32,
}

impl CpTensor {
    /// Construct, validating consistent rank across modes.
    pub fn new(factors: Vec<Factor>) -> Result<Self> {
        if factors.is_empty() {
            return Err(Error::InvalidParameter("CP tensor needs ≥1 mode".into()));
        }
        let r = factors[0].r;
        if factors.iter().any(|f| f.r != r) {
            return Err(Error::ShapeMismatch("CP factor ranks differ".into()));
        }
        Ok(CpTensor { factors, scale: 1.0 })
    }

    /// IID Gaussian factors — a generic random low-rank tensor (workloads).
    pub fn random_gaussian(rng: &mut Rng, dims: &[usize], rank: usize) -> Self {
        let factors = dims
            .iter()
            .map(|&d| {
                let mut f = Factor::zeros(d, rank);
                rng.fill_normal_f32(&mut f.data);
                f
            })
            .collect();
        CpTensor { factors, scale: 1.0 }
    }

    /// CP-distributed random tensor with entries from `sampler` and the
    /// 1/√R normalization of Definition 6 (`CP_Rad(R)` / `CP_N(R)`).
    pub fn random_projection(
        rng: &mut Rng,
        dims: &[usize],
        rank: usize,
        sampler: &dyn Sampler,
    ) -> Self {
        let factors = dims
            .iter()
            .map(|&d| {
                let mut f = Factor::zeros(d, rank);
                sampler.fill(rng, &mut f.data);
                f
            })
            .collect();
        CpTensor { factors, scale: 1.0 / (rank as f32).sqrt() }
    }

    /// Tensor order N.
    pub fn order(&self) -> usize {
        self.factors.len()
    }

    /// Mode dimensions.
    pub fn dims(&self) -> Vec<usize> {
        self.factors.iter().map(|f| f.d).collect()
    }

    /// CP rank R.
    pub fn rank(&self) -> usize {
        self.factors[0].r
    }

    /// Stored parameter count (`O(NdR)` — the Tables 1–2 space column).
    pub fn param_count(&self) -> usize {
        self.factors.iter().map(|f| f.data.len()).sum()
    }

    /// Materialize to dense (O(R·d^N); reference/test path).
    pub fn materialize(&self) -> DenseTensor {
        let dims = self.dims();
        let mut out = DenseTensor::zeros(&dims);
        let r = self.rank();
        let n = self.order();
        let mut idx = vec![0usize; n];
        for flat in 0..out.data.len() {
            let mut acc = 0.0f64;
            for s in 0..r {
                let mut term = 1.0f64;
                for (ax, f) in self.factors.iter().enumerate() {
                    term *= f.get(idx[ax], s) as f64;
                }
                acc += term;
            }
            out.data[flat] = (acc * self.scale as f64) as f32;
            for ax in (0..n).rev() {
                idx[ax] += 1;
                if idx[ax] < dims[ax] {
                    break;
                }
                idx[ax] = 0;
            }
        }
        out
    }

    /// Frobenius norm without materializing: ‖X‖² = scale²·Σ_{r,s} Π_n
    /// (A⁽ⁿ⁾ᵀA⁽ⁿ⁾)[r,s] — O(NdR²).
    pub fn frob_norm(&self) -> f64 {
        let r = self.rank();
        let mut had = vec![1.0f64; r * r];
        for f in &self.factors {
            // Gram = A^T A, accumulated in f64.
            for a in 0..r {
                for b in 0..r {
                    let mut g = 0.0f64;
                    for i in 0..f.d {
                        g += f.get(i, a) as f64 * f.get(i, b) as f64;
                    }
                    had[a * r + b] *= g;
                }
            }
        }
        let sum: f64 = had.iter().sum();
        (self.scale as f64).abs() * sum.max(0.0).sqrt()
    }

    /// Convert to TT format exactly: bond ranks = CP rank R, middle cores are
    /// diagonal stacks `Gₙ[r, i, r'] = δ_{rr'}·A⁽ⁿ⁾[i, r]` — O(NdR²) space.
    pub fn to_tt(&self) -> TtTensor {
        let n = self.order();
        let r = self.rank();
        let mut cores = Vec::with_capacity(n);
        for (ax, f) in self.factors.iter().enumerate() {
            let (r0, r1) = (
                if ax == 0 { 1 } else { r },
                if ax == n - 1 { 1 } else { r },
            );
            let mut core = TtCore::zeros(r0, f.d, r1);
            for i in 0..f.d {
                for s in 0..r {
                    let v = f.get(i, s);
                    match (ax == 0, ax == n - 1) {
                        (true, true) => {
                            // order-1 tensor: sum over rank collapses here
                            let cur = core.get(0, i, 0);
                            core.set(0, i, 0, cur + v);
                        }
                        (true, false) => core.set(0, i, s, v),
                        (false, true) => core.set(s, i, 0, v),
                        (false, false) => core.set(s, i, s, v),
                    }
                }
            }
            cores.push(core);
        }
        TtTensor { cores, scale: self.scale }
    }

    /// CP sum: concatenate rank terms (folds both scales into the first
    /// factor so the result has scale 1). `alpha*self + beta*other`.
    pub fn add_scaled(&self, alpha: f32, other: &CpTensor, beta: f32) -> Result<CpTensor> {
        super::check_same_shape(&self.dims(), &other.dims())?;
        let n = self.order();
        let mut factors = Vec::with_capacity(n);
        for ax in 0..n {
            let (fa, fb) = (&self.factors[ax], &other.factors[ax]);
            let mut f = Factor::zeros(fa.d, fa.r + fb.r);
            let (sa, sb) = if ax == 0 {
                (alpha * self.scale, beta * other.scale)
            } else {
                (1.0, 1.0)
            };
            for i in 0..fa.d {
                for j in 0..fa.r {
                    f.set(i, j, sa * fa.get(i, j));
                }
                for j in 0..fb.r {
                    f.set(i, fa.r + j, sb * fb.get(i, j));
                }
            }
            factors.push(f);
        }
        Ok(CpTensor { factors, scale: 1.0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{GaussianSampler, RademacherSampler};

    #[test]
    fn rank_one_materialize_known() {
        // X = a ∘ b with a=[1,2], b=[3,4,5]
        let mut fa = Factor::zeros(2, 1);
        fa.data = vec![1.0, 2.0];
        let mut fb = Factor::zeros(3, 1);
        fb.data = vec![3.0, 4.0, 5.0];
        let t = CpTensor::new(vec![fa, fb]).unwrap();
        let d = t.materialize();
        assert_eq!(d.data, vec![3.0, 4.0, 5.0, 6.0, 8.0, 10.0]);
    }

    #[test]
    fn frob_norm_matches_materialized() {
        let mut rng = Rng::new(10);
        let t = CpTensor::random_gaussian(&mut rng, &[4, 5, 3], 3);
        let d = t.materialize();
        assert!((t.frob_norm() - d.frob_norm()).abs() < 1e-3);
    }

    #[test]
    fn projection_scale_applied() {
        let mut rng = Rng::new(11);
        let t = CpTensor::random_projection(&mut rng, &[3, 3], 4, &RademacherSampler);
        assert!((t.scale - 0.5).abs() < 1e-7);
        assert!(t.factors.iter().all(|f| f.data.iter().all(|&v| v == 1.0 || v == -1.0)));
        let g = CpTensor::random_projection(&mut rng, &[3, 3], 4, &GaussianSampler);
        assert!(g.factors[0].data.iter().any(|&v| v.abs() > 1e-4 && v.abs() != 1.0));
    }

    #[test]
    fn to_tt_preserves_entries() {
        let mut rng = Rng::new(12);
        for dims in [vec![3usize, 4], vec![3, 4, 2], vec![2, 3, 2, 3]] {
            let t = CpTensor::random_gaussian(&mut rng, &dims, 3);
            let tt = t.to_tt();
            let (a, b) = (t.materialize(), tt.materialize());
            for (x, y) in a.data.iter().zip(&b.data) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn add_scaled_matches_dense() {
        let mut rng = Rng::new(13);
        let a = CpTensor::random_gaussian(&mut rng, &[3, 4, 2], 2);
        let b = CpTensor::random_gaussian(&mut rng, &[3, 4, 2], 3);
        let s = a.add_scaled(2.0, &b, -0.5).unwrap();
        assert_eq!(s.rank(), 5);
        let mut expect = a.materialize();
        expect.scale(2.0);
        expect.axpy(-0.5, &b.materialize()).unwrap();
        let got = s.materialize();
        for (x, y) in got.data.iter().zip(&expect.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn new_validates_ranks() {
        let fa = Factor::zeros(2, 2);
        let fb = Factor::zeros(3, 3);
        assert!(CpTensor::new(vec![fa, fb]).is_err());
        assert!(CpTensor::new(vec![]).is_err());
    }
}
