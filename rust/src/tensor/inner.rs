//! Inner products between tensor formats — the hash hot path.
//!
//! Each pairing implements the algorithm behind the complexity claims of the
//! paper's Tables 1–2 (via Remarks 1–2 / Rakhshan & Rabusseau):
//!
//! | pairing      | algorithm                         | cost                  |
//! |--------------|-----------------------------------|-----------------------|
//! | cp · cp      | Hadamard product of per-mode Grams| `O(Nd·max{R,R̂}²)`     |
//! | tt · tt      | transfer-matrix sweep             | `O(Nd·max{R,R̂}³)`     |
//! | cp · tt      | delta-structured transfer sweep   | `O(Nd·max{R,R̂}³)`     |
//! | dense · dense| flat dot product                  | `O(d^N)`              |
//! | dense · cp   | sequential mode contraction       | `O(R̂·d^N)`            |
//! | dense · tt   | sequential core contraction       | `O(R̂²·d^N)`           |
//!
//! All accumulation is f64; inputs are f32 tensors.

use super::cp::CpTensor;
use super::dense::DenseTensor;
use super::tt::TtTensor;

/// ⟨X, Y⟩ for dense tensors: flat dot product.
pub fn dense_dense(a: &DenseTensor, b: &DenseTensor) -> f64 {
    debug_assert_eq!(a.shape, b.shape);
    let mut acc = 0.0f64;
    for (x, y) in a.data.iter().zip(&b.data) {
        acc += (*x as f64) * (*y as f64);
    }
    acc
}

/// ⟨X, Y⟩ for CP tensors via the Hadamard product of per-mode Gram matrices:
/// `Σ_{r,s} Π_n (A⁽ⁿ⁾ᵀ B⁽ⁿ⁾)[r,s]` — `O(Nd·RaRb)` = `O(Nd·max{R,R̂}²)`.
pub fn cp_cp(a: &CpTensor, b: &CpTensor) -> f64 {
    let (ra, rb) = (a.rank(), b.rank());
    // Stack buffers for the common small-rank case (no allocation on the
    // re-ranking hot path); heap fallback for very high ranks.
    const STACK: usize = 256;
    if ra * rb <= STACK {
        let mut had = [1.0f64; STACK];
        let mut gram = [0.0f64; STACK];
        let m = ra * rb;
        for (fa, fb) in a.factors.iter().zip(&b.factors) {
            gram[..m].iter_mut().for_each(|v| *v = 0.0);
            for i in 0..fa.d {
                let ar = fa.row(i);
                let br = fb.row(i);
                for (p, &av) in ar.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let av = av as f64;
                    let grow = &mut gram[p * rb..(p + 1) * rb];
                    for (g, &bv) in grow.iter_mut().zip(br) {
                        *g += av * bv as f64;
                    }
                }
            }
            for (h, g) in had[..m].iter_mut().zip(&gram[..m]) {
                *h *= *g;
            }
        }
        let sum: f64 = had[..m].iter().sum();
        return sum * a.scale as f64 * b.scale as f64;
    }
    let mut had = vec![1.0f64; ra * rb];
    let mut gram = vec![0.0f64; ra * rb];
    for (fa, fb) in a.factors.iter().zip(&b.factors) {
        gram.iter_mut().for_each(|v| *v = 0.0);
        // Gram = Faᵀ Fb, accumulated row-of-Fa × row-of-Fb (cache friendly:
        // both rows are contiguous).
        for i in 0..fa.d {
            let ar = fa.row(i);
            let br = fb.row(i);
            for (p, &av) in ar.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let av = av as f64;
                let grow = &mut gram[p * rb..(p + 1) * rb];
                for (q, &bv) in br.iter().enumerate() {
                    grow[q] += av * bv as f64;
                }
            }
        }
        for (h, g) in had.iter_mut().zip(&gram) {
            *h *= *g;
        }
    }
    let sum: f64 = had.iter().sum();
    sum * a.scale as f64 * b.scale as f64
}

/// ⟨X, Y⟩ for TT tensors via the transfer-matrix sweep:
/// `M ← Σ_i (Gₐ[:,i,:] ⊗ G_b[:,i,:])ᵀ M` — `O(Nd·r²·r̂ + Nd·r·r̂²)`.
pub fn tt_tt(a: &TtTensor, b: &TtTensor) -> f64 {
    // M[p, q]: bond-p of a × bond-q of b. Starts 1×1 = [1].
    let mut m = vec![1.0f64];
    let (mut pa, mut pb) = (1usize, 1usize);
    for (ca, cb) in a.cores.iter().zip(&b.cores) {
        let (na, nb) = (ca.r1, cb.r1);
        // tmp[i, q, p'] = Σ_p m[p, q] · ca[p, i, p']
        let mut tmp = vec![0.0f64; ca.d * pb * na];
        for p in 0..pa {
            for q in 0..pb {
                let mv = m[p * pb + q];
                if mv == 0.0 {
                    continue;
                }
                for i in 0..ca.d {
                    let base = (i * pb + q) * na;
                    for ap in 0..na {
                        tmp[base + ap] += mv * ca.get(p, i, ap) as f64;
                    }
                }
            }
        }
        // m'[p', q'] = Σ_{i, q} tmp[i, q, p'] · cb[q, i, q']
        let mut next = vec![0.0f64; na * nb];
        for i in 0..ca.d {
            for q in 0..pb {
                let tbase = (i * pb + q) * na;
                for qp in 0..nb {
                    let bv = cb.get(q, i, qp) as f64;
                    if bv == 0.0 {
                        continue;
                    }
                    for ap in 0..na {
                        next[ap * nb + qp] += tmp[tbase + ap] * bv;
                    }
                }
            }
        }
        m = next;
        pa = na;
        pb = nb;
    }
    m[0] * a.scale as f64 * b.scale as f64
}

/// ⟨X, Y⟩ for CP × TT without converting: exploit the delta structure of the
/// CP-as-TT cores. Maintains M ∈ R^{R̂×r}:
/// `M'[s, q'] = Σ_{i, q} A⁽ⁿ⁾[i, s] · M[s, q] · G⁽ⁿ⁾[q, i, q']`
/// — `O(Nd·R̂·r²)` = `O(Nd·max{R,R̂}³)`.
pub fn cp_tt(a: &CpTensor, b: &TtTensor) -> f64 {
    let ra = a.rank();
    let mut m: Vec<f64> = vec![1.0; ra]; // bond dim of b starts at 1
    let mut pb = 1usize;
    for (fa, cb) in a.factors.iter().zip(&b.cores) {
        let nb = cb.r1;
        let mut next = vec![0.0f64; ra * nb];
        for i in 0..fa.d {
            let arow = fa.row(i);
            for q in 0..pb {
                for qp in 0..nb {
                    let bv = cb.get(q, i, qp) as f64;
                    if bv == 0.0 {
                        continue;
                    }
                    for (s, &av) in arow.iter().enumerate() {
                        next[s * nb + qp] += av as f64 * m[s * pb + q] * bv;
                    }
                }
            }
        }
        m = next;
        pb = nb;
    }
    let sum: f64 = m.iter().sum();
    sum * a.scale as f64 * b.scale as f64
}

/// ⟨X, P⟩ for dense × CP via simultaneous mode contraction:
/// contract X's first mode with all R̂ columns at once, then sweep.
/// Cost `O(R̂·d^N)` — first contraction dominates.
pub fn dense_cp(x: &DenseTensor, p: &CpTensor) -> f64 {
    let r = p.rank();
    let dims = p.dims();
    let n = dims.len();
    // acc[s, rest]: per-rank partially contracted tensor, rest shrinks.
    let d0 = dims[0];
    let rest0 = x.data.len() / d0;
    let f0 = &p.factors[0];
    let mut acc = vec![0.0f64; r * rest0];
    for i in 0..d0 {
        let xrow = &x.data[i * rest0..(i + 1) * rest0];
        let frow = f0.row(i);
        for (s, &fv) in frow.iter().enumerate() {
            if fv == 0.0 {
                continue;
            }
            let fv = fv as f64;
            let arow = &mut acc[s * rest0..(s + 1) * rest0];
            for (av, &xv) in arow.iter_mut().zip(xrow) {
                *av += fv * xv as f64;
            }
        }
    }
    let mut rest = rest0;
    for ax in 1..n {
        let d = dims[ax];
        let new_rest = rest / d;
        let f = &p.factors[ax];
        let mut next = vec![0.0f64; r * new_rest];
        for s in 0..r {
            for i in 0..d {
                let fv = f.get(i, s) as f64;
                if fv == 0.0 {
                    continue;
                }
                let abase = s * rest + i * new_rest;
                let nbase = s * new_rest;
                for j in 0..new_rest {
                    next[nbase + j] += fv * acc[abase + j];
                }
            }
        }
        acc = next;
        rest = new_rest;
    }
    debug_assert_eq!(rest, 1);
    let sum: f64 = (0..r).map(|s| acc[s]).sum();
    sum * p.scale as f64
}

/// ⟨X, T⟩ for dense × TT via sequential core contraction:
/// `W₀ = X`, `Wₙ[b, rest] = Σ_{a,i} Gₙ[a,i,b]·Wₙ₋₁[a, i, rest]`.
/// Cost `O(r̂²·d^N)` — first contractions dominate.
pub fn dense_tt(x: &DenseTensor, t: &TtTensor) -> f64 {
    let dims = t.dims();
    let n = dims.len();
    // w: (bond, rest) row-major, starts (1, d^N) = X.
    let mut w: Vec<f64> = x.data.iter().map(|&v| v as f64).collect();
    let mut bond = 1usize;
    let mut rest = w.len();
    for ax in 0..n {
        let core = &t.cores[ax];
        let d = dims[ax];
        let new_rest = rest / d;
        let nb = core.r1;
        let mut next = vec![0.0f64; nb * new_rest];
        for a in 0..bond {
            for i in 0..d {
                let wbase = (a * d + i) * new_rest;
                for b in 0..nb {
                    let gv = core.get(a, i, b) as f64;
                    if gv == 0.0 {
                        continue;
                    }
                    let nbase = b * new_rest;
                    for j in 0..new_rest {
                        next[nbase + j] += gv * w[wbase + j];
                    }
                }
            }
        }
        w = next;
        bond = nb;
        rest = new_rest;
    }
    debug_assert_eq!(bond * rest, 1);
    w[0] * t.scale as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn close(a: f64, b: f64) {
        assert!((a - b).abs() < 2e-3 * (1.0 + b.abs()), "{a} vs {b}");
    }

    #[test]
    fn cp_cp_matches_dense() {
        let mut rng = Rng::new(30);
        let a = CpTensor::random_gaussian(&mut rng, &[4, 5, 3], 3);
        let mut b = CpTensor::random_gaussian(&mut rng, &[4, 5, 3], 2);
        b.scale = 0.7;
        close(cp_cp(&a, &b), dense_dense(&a.materialize(), &b.materialize()));
    }

    #[test]
    fn tt_tt_matches_dense() {
        let mut rng = Rng::new(31);
        let a = TtTensor::random_gaussian(&mut rng, &[4, 3, 5], 3);
        let mut b = TtTensor::random_gaussian(&mut rng, &[4, 3, 5], 2);
        b.scale = -1.3;
        close(tt_tt(&a, &b), dense_dense(&a.materialize(), &b.materialize()));
    }

    #[test]
    fn cp_tt_matches_dense_and_conversion() {
        let mut rng = Rng::new(32);
        let a = CpTensor::random_gaussian(&mut rng, &[3, 4, 2, 3], 3);
        let b = TtTensor::random_gaussian(&mut rng, &[3, 4, 2, 3], 2);
        let direct = cp_tt(&a, &b);
        close(direct, dense_dense(&a.materialize(), &b.materialize()));
        // also agree with converting CP→TT then tt_tt
        close(direct, tt_tt(&a.to_tt(), &b));
    }

    #[test]
    fn dense_cp_matches_dense() {
        let mut rng = Rng::new(33);
        let x = DenseTensor::random_gaussian(&mut rng, &[4, 3, 5]);
        let mut p = CpTensor::random_gaussian(&mut rng, &[4, 3, 5], 3);
        p.scale = 0.25;
        close(dense_cp(&x, &p), dense_dense(&x, &p.materialize()));
    }

    #[test]
    fn dense_tt_matches_dense() {
        let mut rng = Rng::new(34);
        let x = DenseTensor::random_gaussian(&mut rng, &[4, 3, 5]);
        let mut t = TtTensor::random_gaussian(&mut rng, &[4, 3, 5], 3);
        t.scale = 2.0;
        close(dense_tt(&x, &t), dense_dense(&x, &t.materialize()));
    }

    #[test]
    fn inner_with_self_is_norm_squared() {
        let mut rng = Rng::new(35);
        let a = CpTensor::random_gaussian(&mut rng, &[5, 4, 3], 2);
        close(cp_cp(&a, &a), a.frob_norm().powi(2));
        let t = TtTensor::random_gaussian(&mut rng, &[5, 4, 3], 2);
        close(tt_tt(&t, &t), t.frob_norm().powi(2));
    }

    #[test]
    fn order_one_tensors() {
        // N=1 edge case: everything is a plain dot product.
        let mut rng = Rng::new(36);
        let x = DenseTensor::random_gaussian(&mut rng, &[7]);
        let p = CpTensor::random_gaussian(&mut rng, &[7], 2);
        let t = TtTensor::random_gaussian(&mut rng, &[7], 1);
        close(dense_cp(&x, &p), dense_dense(&x, &p.materialize()));
        close(dense_tt(&x, &t), dense_dense(&x, &t.materialize()));
        close(cp_tt(&p, &t), dense_dense(&p.materialize(), &t.materialize()));
    }
}
