//! Dense row-major N-dimensional tensor.

// Not the precision-audited hash path: tensor values are stored f32 by design (see README §Layout).
#![allow(clippy::cast_possible_truncation)]

use super::{numel, strides};
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::rng::Rng;

/// Dense row-major tensor of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl DenseTensor {
    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        DenseTensor { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    /// Build from shape + flat data.
    pub fn from_data(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        if data.len() != numel(shape) {
            return Err(Error::ShapeMismatch(format!(
                "from_data: shape {:?} needs {} elements, got {}",
                shape,
                numel(shape),
                data.len()
            )));
        }
        Ok(DenseTensor { shape: shape.to_vec(), data })
    }

    /// Build elementwise from multi-index.
    pub fn from_fn(shape: &[usize], mut f: impl FnMut(&[usize]) -> f32) -> Self {
        let mut t = DenseTensor::zeros(shape);
        let n = t.data.len();
        let mut idx = vec![0usize; shape.len()];
        for flat in 0..n {
            t.data[flat] = f(&idx);
            // advance multi-index (row-major)
            for ax in (0..shape.len()).rev() {
                idx[ax] += 1;
                if idx[ax] < shape[ax] {
                    break;
                }
                idx[ax] = 0;
            }
        }
        t
    }

    /// IID standard normal entries.
    pub fn random_gaussian(rng: &mut Rng, shape: &[usize]) -> Self {
        let mut t = DenseTensor::zeros(shape);
        rng.fill_normal_f32(&mut t.data);
        t
    }

    /// Flat offset of a multi-index.
    #[inline]
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let st = strides(&self.shape);
        idx.iter().zip(&st).map(|(i, s)| i * s).sum()
    }

    /// Element access by multi-index.
    #[inline]
    pub fn get(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    /// Mutable element access.
    #[inline]
    pub fn get_mut(&mut self, idx: &[usize]) -> &mut f32 {
        let o = self.offset(idx);
        &mut self.data[o]
    }

    /// Frobenius norm (f64 accumulation).
    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Max |entry| (the paper's ‖X‖_max).
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// In-place scale.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// Elementwise `self + alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &DenseTensor) -> Result<()> {
        super::check_same_shape(&self.shape, &other.shape)?;
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
        Ok(())
    }

    /// Mode-n unfolding as an f64 matrix of shape (dₙ, D/dₙ).
    ///
    /// Columns are indexed by the remaining indices in row-major order with
    /// mode n removed — the convention CP-ALS and TT-SVD below rely on.
    pub fn unfold_mode(&self, mode: usize) -> Matrix {
        let n = self.shape.len();
        assert!(mode < n);
        let dn = self.shape[mode];
        let rest = self.data.len() / dn;
        let st = strides(&self.shape);
        let mut m = Matrix::zeros(dn, rest);
        // Iterate all elements; compute (row=idx[mode], col=rank of remaining).
        let mut idx = vec![0usize; n];
        for flat in 0..self.data.len() {
            let mut col = 0usize;
            for ax in 0..n {
                if ax == mode {
                    continue;
                }
                col = col * self.shape[ax] + idx[ax];
            }
            m[(idx[mode], col)] = self.data[flat] as f64;
            let _ = st; // strides kept for clarity; flat order matches idx walk
            for ax in (0..n).rev() {
                idx[ax] += 1;
                if idx[ax] < self.shape[ax] {
                    break;
                }
                idx[ax] = 0;
            }
        }
        m
    }

    /// Reshape (same numel) — returns a view-copy with the new shape.
    pub fn reshape(&self, shape: &[usize]) -> Result<DenseTensor> {
        if numel(shape) != self.data.len() {
            return Err(Error::ShapeMismatch(format!(
                "reshape {:?} -> {:?}",
                self.shape, shape
            )));
        }
        Ok(DenseTensor { shape: shape.to_vec(), data: self.data.clone() })
    }

    /// Normalize to unit Frobenius norm (no-op on zero tensors).
    pub fn normalize(&mut self) {
        let n = self.frob_norm();
        if n > 0.0 {
            self.scale((1.0 / n) as f32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_fn_and_get_roundtrip() {
        let t = DenseTensor::from_fn(&[2, 3, 4], |idx| {
            (idx[0] * 100 + idx[1] * 10 + idx[2]) as f32
        });
        assert_eq!(t.get(&[1, 2, 3]), 123.0);
        assert_eq!(t.get(&[0, 0, 0]), 0.0);
        assert_eq!(t.get(&[1, 0, 2]), 102.0);
    }

    #[test]
    fn from_data_validates() {
        assert!(DenseTensor::from_data(&[2, 2], vec![0.0; 3]).is_err());
        assert!(DenseTensor::from_data(&[2, 2], vec![0.0; 4]).is_ok());
    }

    #[test]
    fn unfold_mode_matches_definition() {
        // 2x3 matrix as a tensor: unfold(0) == itself, unfold(1) == transpose.
        let t = DenseTensor::from_data(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let m0 = t.unfold_mode(0);
        assert_eq!(m0.data, vec![1., 2., 3., 4., 5., 6.]);
        let m1 = t.unfold_mode(1);
        assert_eq!(m1.data, vec![1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn unfold_preserves_norm() {
        let mut rng = crate::rng::Rng::new(3);
        let t = DenseTensor::random_gaussian(&mut rng, &[3, 4, 5]);
        for mode in 0..3 {
            let m = t.unfold_mode(mode);
            assert!((m.frob_norm() - t.frob_norm()).abs() < 1e-4);
        }
    }

    #[test]
    fn axpy_and_norm() {
        let mut a = DenseTensor::from_data(&[2], vec![1.0, 2.0]).unwrap();
        let b = DenseTensor::from_data(&[2], vec![3.0, -1.0]).unwrap();
        a.axpy(2.0, &b).unwrap();
        assert_eq!(a.data, vec![7.0, 0.0]);
        assert!((a.frob_norm() - 7.0).abs() < 1e-12);
    }

    #[test]
    fn normalize_unit_norm() {
        let mut rng = crate::rng::Rng::new(4);
        let mut t = DenseTensor::random_gaussian(&mut rng, &[4, 4]);
        t.normalize();
        assert!((t.frob_norm() - 1.0).abs() < 1e-6);
    }
}
