//! Distribution samplers with a common interface.
//!
//! The projection families (`projection::*`) are generic over the entry
//! distribution: the paper defines CP/TT-Rademacher tensors (Definitions 6–7)
//! and notes the Gaussian variants; both yield the same asymptotic law, and
//! the benches ablate them.

// Not the precision-audited hash path: bit-twiddling narrows intentionally (sampler mixing).
#![allow(clippy::cast_possible_truncation)]

use super::Rng;

/// A scalar distribution sampler that fills f32 buffers.
pub trait Sampler: Send + Sync {
    /// Draw a single deviate.
    fn sample(&self, rng: &mut Rng) -> f32;

    /// Fill a buffer with iid deviates.
    fn fill(&self, rng: &mut Rng, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.sample(rng);
        }
    }

    /// Variance of the distribution (used in space/variance accounting).
    fn variance(&self) -> f64;

    /// Display name for reports.
    fn name(&self) -> &'static str;
}

/// Rademacher ±1 entries (Definition 6/7 of the paper).
#[derive(Clone, Copy, Debug, Default)]
pub struct RademacherSampler;

impl Sampler for RademacherSampler {
    #[inline]
    fn sample(&self, rng: &mut Rng) -> f32 {
        rng.rademacher()
    }

    fn fill(&self, rng: &mut Rng, out: &mut [f32]) {
        rng.fill_rademacher_f32(out);
    }

    fn variance(&self) -> f64 {
        1.0
    }

    fn name(&self) -> &'static str {
        "rademacher"
    }
}

/// Standard normal entries (the CP/TT-Gaussian variants).
#[derive(Clone, Copy, Debug, Default)]
pub struct GaussianSampler;

impl Sampler for GaussianSampler {
    #[inline]
    fn sample(&self, rng: &mut Rng) -> f32 {
        rng.normal() as f32
    }

    fn fill(&self, rng: &mut Rng, out: &mut [f32]) {
        rng.fill_normal_f32(out);
    }

    fn variance(&self) -> f64 {
        1.0
    }

    fn name(&self) -> &'static str {
        "gaussian"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rademacher_fill_matches_scalar_path_distribution() {
        let mut rng = Rng::new(1);
        let mut buf = vec![0.0f32; 1000];
        RademacherSampler.fill(&mut rng, &mut buf);
        assert!(buf.iter().all(|&v| v == 1.0 || v == -1.0));
    }

    #[test]
    fn gaussian_fill_mean_near_zero() {
        let mut rng = Rng::new(2);
        let mut buf = vec![0.0f32; 50_000];
        GaussianSampler.fill(&mut rng, &mut buf);
        let mean: f64 = buf.iter().map(|&v| v as f64).sum::<f64>() / buf.len() as f64;
        assert!(mean.abs() < 0.02);
    }
}
