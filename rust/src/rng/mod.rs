//! Deterministic, splittable random number generation.
//!
//! Everything stochastic in the library (projection tensors, offsets,
//! workloads) flows through [`Rng`], a xoshiro256++ generator seeded via
//! SplitMix64. Streams are derived with [`Rng::derive`] so that e.g. table
//! `t`, hash `k`, mode `n` gets an independent, *reproducible* substream —
//! the property the paper's hash families need (the same `(seed, k)` must
//! regenerate the same projection tensor on every node, and in both the
//! native and the AOT/PJRT hash paths).

// Not the precision-audited hash path: bit-twiddling narrows intentionally (xoshiro mixing).
#![allow(clippy::cast_possible_truncation)]

mod sampler;

pub use sampler::{GaussianSampler, RademacherSampler, Sampler};

/// SplitMix64 step — used for seeding and stream derivation.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG (Blackman & Vigna). Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached spare normal deviate (polar method produces pairs).
    spare_normal: Option<f64>,
    /// Bit pool for cheap Rademacher draws.
    bit_pool: u64,
    bits_left: u32,
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None, bit_pool: 0, bits_left: 0 }
    }

    /// Derive an independent substream keyed by `ids` (e.g. `[table, k, mode]`).
    ///
    /// Mixing is hash-based (SplitMix64 over the concatenation), so derived
    /// streams are stable across program runs and node boundaries.
    pub fn derive(seed: u64, ids: &[u64]) -> Self {
        let mut state = seed ^ 0xD1B5_4A32_D192_ED03;
        let mut acc = splitmix64(&mut state);
        for &id in ids {
            state ^= id.wrapping_mul(0x9E3779B97F4A7C15);
            acc ^= splitmix64(&mut state).rotate_left(17);
        }
        Rng::new(acc)
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal deviate (Marsaglia polar method, pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * f);
                return u * f;
            }
        }
    }

    /// Rademacher deviate: ±1 with probability 1/2 each (bit-pooled).
    #[inline]
    pub fn rademacher(&mut self) -> f32 {
        if self.bits_left == 0 {
            self.bit_pool = self.next_u64();
            self.bits_left = 64;
        }
        let bit = self.bit_pool & 1;
        self.bit_pool >>= 1;
        self.bits_left -= 1;
        if bit == 1 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fill a slice with standard normals (f32).
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }

    /// Fill a slice with Rademacher ±1 (f32).
    pub fn fill_rademacher_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.rademacher();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Zipf-distributed index in [0, n) with exponent `s` (inverse-CDF on the
    /// precomputed harmonic weights is overkill; rejection sampling is fine
    /// for workload generation).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse transform on H_{n,s} computed incrementally would be O(n);
        // use the standard rejection sampler for the Zipf distribution.
        debug_assert!(n > 0);
        if n == 1 {
            return 0;
        }
        let nf = n as f64;
        loop {
            let u = self.next_f64();
            let v = self.next_f64();
            let x = ((nf + 1.0).powf(1.0 - s) * u + (1.0 - u)).powf(1.0 / (1.0 - s));
            let k = x.floor().max(1.0);
            if k <= nf {
                let ratio = (k / x).powf(s) * x / k;
                if v * ratio <= 1.0 {
                    return k as usize - 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(123);
        let mut b = Rng::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn derive_is_stable_and_independent() {
        let mut a1 = Rng::derive(7, &[1, 2, 3]);
        let mut a2 = Rng::derive(7, &[1, 2, 3]);
        let mut b = Rng::derive(7, &[1, 2, 4]);
        assert_eq!(a1.next_u64(), a2.next_u64());
        let mut collisions = 0;
        for _ in 0..64 {
            if a1.next_u64() == b.next_u64() {
                collisions += 1;
            }
        }
        assert!(collisions < 2);
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::new(42);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.uniform(2.0, 4.0);
            assert!((2.0..4.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 3.0).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(42);
        let n = 100_000;
        let (mut m1, mut m2, mut m4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            m1 += v;
            m2 += v * v;
            m4 += v * v * v * v;
        }
        let nf = n as f64;
        assert!((m1 / nf).abs() < 0.02);
        assert!((m2 / nf - 1.0).abs() < 0.02);
        assert!((m4 / nf - 3.0).abs() < 0.1);
    }

    #[test]
    fn rademacher_is_pm_one_and_balanced() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let mut pos = 0usize;
        for _ in 0..n {
            let v = r.rademacher();
            assert!(v == 1.0 || v == -1.0);
            if v == 1.0 {
                pos += 1;
            }
        }
        let frac = pos as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.01, "frac={frac}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0);
        }
    }

    #[test]
    fn zipf_skews_to_head() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 100];
        for _ in 0..50_000 {
            counts[r.zipf(100, 1.2)] += 1;
        }
        assert!(counts[0] > counts[9]);
        assert!(counts[9] > counts[60]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
