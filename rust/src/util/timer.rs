//! Micro-benchmark timing helpers (criterion is unavailable offline; the
//! bench binaries use this instead: warmup + adaptive iteration count +
//! robust statistics).

// Not the precision-audited hash path: nanosecond counters fit the cast range for any real run.
#![allow(clippy::cast_possible_truncation)]

use std::time::Instant;

/// Result of a timed measurement.
#[derive(Clone, Debug)]
pub struct Timing {
    /// Median nanoseconds per iteration.
    pub median_ns: f64,
    /// Mean nanoseconds per iteration.
    pub mean_ns: f64,
    /// 99th-percentile nanoseconds per iteration across samples (the max
    /// sample unless `samples` ≥ 100 — the bench reports it for the
    /// machine-readable BENCH_*.json trajectory files).
    pub p99_ns: f64,
    /// Min / max observed per-iteration time across samples.
    pub min_ns: f64,
    pub max_ns: f64,
    /// Number of timed samples.
    pub samples: usize,
    /// Iterations per sample.
    pub iters_per_sample: usize,
}

impl Timing {
    /// Throughput in ops/sec at the median.
    pub fn ops_per_sec(&self) -> f64 {
        1e9 / self.median_ns
    }
}

/// Benchmark a closure: warm up, pick an iteration count that makes each
/// sample ≥ `min_sample_ms`, then take `samples` samples and report robust
/// statistics. The closure should return something observable to prevent
/// dead-code elimination; we black-box it.
pub fn bench<T>(mut f: impl FnMut() -> T, samples: usize, min_sample_ms: f64) -> Timing {
    // Warmup + calibration.
    let mut iters = 1usize;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        let elapsed = t0.elapsed().as_secs_f64() * 1e3;
        if elapsed >= min_sample_ms || iters >= 1 << 24 {
            break;
        }
        let growth = if elapsed <= 0.01 {
            16.0
        } else {
            (min_sample_ms / elapsed * 1.3).max(2.0)
        };
        iters = ((iters as f64) * growth).ceil() as usize;
    }
    let mut per_iter: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(f());
        }
        per_iter.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median_ns = per_iter[per_iter.len() / 2];
    let mean_ns = per_iter.iter().sum::<f64>() / per_iter.len() as f64;
    let p99_idx = (((per_iter.len() as f64 - 1.0) * 0.99).round() as usize)
        .min(per_iter.len() - 1);
    Timing {
        median_ns,
        mean_ns,
        p99_ns: per_iter[p99_idx],
        min_ns: per_iter[0],
        max_ns: *per_iter.last().unwrap(),
        samples,
        iters_per_sample: iters,
    }
}

/// Quick one-shot wall-clock measurement (for long operations).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_nanos() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something_positive() {
        let t = bench(|| (0..100).sum::<u64>(), 5, 0.5);
        assert!(t.median_ns > 0.0);
        assert!(t.min_ns <= t.median_ns && t.median_ns <= t.max_ns);
        assert!(t.median_ns <= t.p99_ns && t.p99_ns <= t.max_ns);
        assert!(t.ops_per_sec() > 0.0);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, ns) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(ns >= 0.0);
    }
}
