//! Small shared utilities: hand-rolled JSON, timing helpers, formatting.

pub mod json;
pub mod timer;

/// Human-friendly duration formatting for reports.
pub fn fmt_duration(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Human-friendly byte count.
pub fn fmt_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn durations() {
        assert_eq!(fmt_duration(500.0), "500 ns");
        assert_eq!(fmt_duration(1500.0), "1.50 µs");
        assert_eq!(fmt_duration(2.5e6), "2.50 ms");
        assert_eq!(fmt_duration(3.2e9), "3.20 s");
    }

    #[test]
    fn bytes() {
        assert_eq!(fmt_bytes(12), "12 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024), "5.00 MiB");
    }
}
