//! Minimal JSON parser/printer (no serde available offline).
//!
//! Supports the full JSON grammar minus exotic number forms; used to read
//! `artifacts/manifest.json` and to emit experiment reports.

// Not the precision-audited hash path: JSON integer parsing is fract()-guarded.
#![allow(clippy::cast_possible_truncation)]

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::Json(format!("expected object, got {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&Vec<Json>> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::Json(format!("expected array, got {self:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Json(format!("expected string, got {self:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(Error::Json(format!("expected number, got {self:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            return Err(Error::Json(format!("expected non-negative integer, got {f}")));
        }
        Ok(f as usize)
    }

    /// Fetch a key from an object.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Json(format!("missing key '{key}'")))
    }

    /// Pretty-print (stable key order via BTreeMap).
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    /// Single-line print (stable key order) — the JSONL event-log form:
    /// one event per line, no interior newlines, parses back with
    /// [`parse`].
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null | Json::Bool(_) | Json::Num(_) | Json::Str(_) => self.write(out, 0),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out, 0);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    let _ = write!(out, "{pad}  ");
                    item.write(out, indent + 1);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                let _ = write!(out, "{pad}]");
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    let _ = write!(out, "{pad}  \"{k}\": ");
                    v.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                let _ = write!(out, "{pad}}}");
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(Error::Json(format!("trailing data at byte {}", p.pos)));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| Error::Json("unexpected end of input".into()))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            return Err(Error::Json(format!(
                "expected '{}' at byte {}, got '{}'",
                b as char,
                self.pos - 1,
                got as char
            )));
        }
        Ok(())
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(Error::Json(format!("invalid literal at byte {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Json(format!("unexpected byte {other:?} at {}", self.pos))),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => break,
                c => return Err(Error::Json(format!("expected , or }} got '{}'", c as char))),
            }
        }
        Ok(Json::Obj(map))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => break,
                c => return Err(Error::Json(format!("expected , or ] got '{}'", c as char))),
            }
        }
        Ok(Json::Arr(v))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.bump()?;
            match b {
                b'"' => break,
                b'\\' => {
                    let e = self.bump()?;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let mut code = 0u32;
                            for _ in 0..4 {
                                let h = self.bump()?;
                                code = code * 16
                                    + (h as char)
                                        .to_digit(16)
                                        .ok_or_else(|| Error::Json("bad \\u escape".into()))?;
                            }
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        c => {
                            return Err(Error::Json(format!("bad escape '\\{}'", c as char)))
                        }
                    }
                }
                _ => {
                    // Re-decode UTF-8: collect continuation bytes.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match b {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        self.pos = start + width;
                        let chunk = &self.bytes[start..self.pos.min(self.bytes.len())];
                        s.push_str(
                            std::str::from_utf8(chunk)
                                .map_err(|_| Error::Json("invalid utf-8".into()))?,
                        );
                    }
                }
            }
        }
        Ok(s)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| Error::Json(format!("bad number '{text}': {e}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{
            "config": {"d": 32, "k": 64, "w": 4.5},
            "artifacts": {"cp_srp": {"file": "cp_srp.hlo.txt", "inputs": [[64, 32, 8]]}},
            "flags": [true, false, null],
            "note": "hello \"world\"\n"
        }"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("config").unwrap().get("d").unwrap().as_usize().unwrap(), 32);
        assert_eq!(v.get("config").unwrap().get("w").unwrap().as_f64().unwrap(), 4.5);
        let arts = v.get("artifacts").unwrap().as_obj().unwrap();
        assert!(arts.contains_key("cp_srp"));
        // print -> parse -> equal
        let printed = v.to_string_pretty();
        let v2 = parse(&printed).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("3").unwrap(), Json::Num(3.0));
        assert_eq!(parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(parse("0.125").unwrap(), Json::Num(0.125));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn nested_arrays() {
        let v = parse("[[1,2],[3,4]]").unwrap();
        let rows = v.as_arr().unwrap();
        assert_eq!(rows[1].as_arr().unwrap()[0].as_f64().unwrap(), 3.0);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }
}
