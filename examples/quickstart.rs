//! Quickstart: the four tensorized hash families in ~60 lines.
//!
//! Run: `cargo run --release --example quickstart`

use tensor_lsh::prelude::*;
use tensor_lsh::workload::{pair_at_cosine, pair_at_distance, PairFormat};

fn main() -> Result<()> {
    let dims = vec![16usize, 16, 16];
    let mut rng = Rng::new(42);

    // A random low-rank tensor in CP format (16×16×16, CP rank 4)…
    let x = AnyTensor::Cp(CpTensor::random_gaussian(&mut rng, &dims, 4));

    // …hashed by CP-E2LSH (Definition 10): K=8 codes, bucket width 4.
    let cp_e2 = CpE2lsh::new(CpE2lshConfig { dims: dims.clone(), rank: 8, k: 8, w: 4.0, seed: 1 });
    println!("CP-E2LSH codes: {:?}", cp_e2.hash(&x));

    // …and by TT-E2LSH (Definition 11), CP-SRP (12), TT-SRP (13).
    let tt_e2 = TtE2lsh::new(TtE2lshConfig { dims: dims.clone(), rank: 8, k: 8, w: 4.0, seed: 1 });
    let cp_srp = CpSrp::new(CpSrpConfig { dims: dims.clone(), rank: 8, k: 8, seed: 1 });
    let tt_srp = TtSrp::new(TtSrpConfig { dims: dims.clone(), rank: 8, k: 8, seed: 1 });
    println!("TT-E2LSH codes: {:?}", tt_e2.hash(&x));
    println!("CP-SRP   bits : {:?}", cp_srp.hash(&x));
    println!("TT-SRP   bits : {:?}", tt_srp.hash(&x));

    // The whole point: space. The naive method stores d^N floats per hash.
    let naive = NaiveSrp::naive(&dims, 8, 1);
    println!(
        "\nprojection parameters: cp-srp {} f32 vs naive {} f32 ({}x smaller)",
        cp_srp.param_count(),
        naive.param_count(),
        naive.param_count() / cp_srp.param_count()
    );

    // Collision probabilities follow the classical laws (Theorems 4 & 8):
    // nearby pairs collide often, far pairs rarely.
    let (near_x, near_y) = pair_at_distance(&mut rng, &dims, 1.0, PairFormat::Cp(2));
    let (far_x, far_y) = pair_at_distance(&mut rng, &dims, 12.0, PairFormat::Cp(2));
    let collide =
        |h: &Vec<i32>, g: &Vec<i32>| h.iter().zip(g).filter(|(a, b)| a == b).count();
    println!(
        "\nE2LSH collisions out of 8 hashes: near(r=1) {} vs far(r=12) {}",
        collide(&cp_e2.hash(&near_x), &cp_e2.hash(&near_y)),
        collide(&cp_e2.hash(&far_x), &cp_e2.hash(&far_y)),
    );
    let (sim_x, sim_y) = pair_at_cosine(&mut rng, &dims, 0.95, PairFormat::Cp(2));
    let (dis_x, dis_y) = pair_at_cosine(&mut rng, &dims, 0.0, PairFormat::Cp(2));
    println!(
        "SRP collisions out of 8 hashes: cos=0.95 {} vs cos=0 {}",
        collide(&cp_srp.hash(&sim_x), &cp_srp.hash(&sim_y)),
        collide(&cp_srp.hash(&dis_x), &cp_srp.hash(&dis_y)),
    );
    Ok(())
}
