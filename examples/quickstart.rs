//! Quickstart: the declarative spec API in ~60 lines — hash with the four
//! tensorized families, then build and search a whole index from one
//! `LshSpec`.
//!
//! Run: `cargo run --release --example quickstart`

// Not the precision-audited hash path: example scaffolding on small bounded values.
#![allow(clippy::cast_possible_truncation)]

use tensor_lsh::prelude::*;
use tensor_lsh::workload::{pair_at_cosine, pair_at_distance, PairFormat};

fn main() -> Result<()> {
    let dims = vec![16usize, 16, 16];
    let mut rng = Rng::new(42);

    // A random low-rank tensor in CP format (16×16×16, CP rank 4)…
    let x = AnyTensor::Cp(CpTensor::random_gaussian(&mut rng, &dims, 4));

    // …hashed by the four families of the paper (Definitions 10–13). One
    // FamilySpec describes any of them; build(seed) instantiates it.
    let cp_e2 = FamilySpec::e2lsh(FamilyKind::Cp, dims.clone(), 8, 8, 4.0).build(1)?;
    let tt_e2 = FamilySpec::e2lsh(FamilyKind::Tt, dims.clone(), 8, 8, 4.0).build(1)?;
    let cp_srp = FamilySpec::srp(FamilyKind::Cp, dims.clone(), 8, 8).build(1)?;
    let tt_srp = FamilySpec::srp(FamilyKind::Tt, dims.clone(), 8, 8).build(1)?;
    println!("CP-E2LSH codes: {:?}", cp_e2.hash(&x));
    println!("TT-E2LSH codes: {:?}", tt_e2.hash(&x));
    println!("CP-SRP   bits : {:?}", cp_srp.hash(&x));
    println!("TT-SRP   bits : {:?}", tt_srp.hash(&x));

    // The whole point: space. The naive method stores d^N floats per hash.
    let naive = FamilySpec::srp(FamilyKind::Naive, dims.clone(), 8, 8).build(1)?;
    println!(
        "\nprojection parameters: cp-srp {} f32 vs naive {} f32 ({}x smaller)",
        cp_srp.param_count(),
        naive.param_count(),
        naive.param_count() / cp_srp.param_count()
    );

    // An entire multi-table index from one serializable spec — this is the
    // whole build, spec to searchable index:
    let items: Vec<AnyTensor> = (0..300)
        .map(|_| AnyTensor::Cp(CpTensor::random_gaussian(&mut rng, &dims, 2)))
        .collect();
    let spec = LshSpec::cosine(FamilyKind::Cp, dims.clone(), 8, 10, 8);
    let index = IndexBuilder::new(spec.clone()).build_with(items.clone())?;

    // Queries are plain-data `Query` values: k plus call-time knobs. The
    // response carries the hits AND what they cost.
    let resp = index.query(&Query::new(items[7].clone(), 5))?;
    assert_eq!(resp.hits[0].id, 7); // an indexed item is its own nearest neighbor
    println!(
        "\nindexed {} items in {} tables; top hit for item 7 is itself (cos {:.3})",
        index.len(),
        index.n_tables(),
        resp.hits[0].score
    );
    println!(
        "the query examined {} candidates across {} tables and re-ranked {}",
        resp.stats.candidates_examined, resp.stats.tables_hit, resp.stats.reranked
    );

    // The recall/latency knobs are per QUERY, not baked into the index:
    // the same built index serves a recall-hungry multiprobe query and a
    // latency-bound budgeted one.
    let tuned = Query::new(items[7].clone(), 5)
        .probes(4)
        .rerank(RerankPolicy::Budgeted(64));
    let tuned_resp = index.query(&tuned)?;
    assert_eq!(tuned_resp.hits[0].id, 7);
    println!(
        "with 4 probes/table + a 64-candidate rerank budget: {} probes spent, \
         {} candidates, {} re-ranked",
        tuned_resp.stats.probes_used,
        tuned_resp.stats.candidates_generated,
        tuned_resp.stats.reranked
    );

    // The spec round-trips through JSON — store it next to the index and
    // every rebuild is bit-identical.
    assert_eq!(LshSpec::from_json_str(&spec.to_json_string())?, spec);
    println!("spec JSON round-trips ({} bytes)", spec.to_json_string().len());

    // And the index itself is durable: one checksummed segment file holds
    // the spec, buckets, items, and norms; loading it back yields a
    // bit-identical searcher — same hits, same per-query stats.
    let seg = std::env::temp_dir().join("tensorlsh_quickstart.seg");
    index.save(&seg)?;
    let reloaded = LshIndex::load(&seg)?;
    let warm = reloaded.query(&Query::new(items[7].clone(), 5))?;
    assert_eq!(warm.hits, resp.hits);
    assert_eq!(warm.stats, resp.stats);
    println!(
        "index survives a save → load round trip ({} on disk, {} items)",
        tensor_lsh::util::fmt_bytes(std::fs::metadata(&seg)?.len() as usize),
        reloaded.len()
    );
    std::fs::remove_file(&seg).ok();

    // Collision probabilities follow the classical laws (Theorems 4 & 8):
    // nearby pairs collide often, far pairs rarely.
    let (near_x, near_y) = pair_at_distance(&mut rng, &dims, 1.0, PairFormat::Cp(2));
    let (far_x, far_y) = pair_at_distance(&mut rng, &dims, 12.0, PairFormat::Cp(2));
    let collide =
        |h: &Vec<i32>, g: &Vec<i32>| h.iter().zip(g).filter(|(a, b)| a == b).count();
    println!(
        "\nE2LSH collisions out of 8 hashes: near(r=1) {} vs far(r=12) {}",
        collide(&cp_e2.hash(&near_x), &cp_e2.hash(&near_y)),
        collide(&cp_e2.hash(&far_x), &cp_e2.hash(&far_y)),
    );
    let (sim_x, sim_y) = pair_at_cosine(&mut rng, &dims, 0.95, PairFormat::Cp(2));
    let (dis_x, dis_y) = pair_at_cosine(&mut rng, &dims, 0.0, PairFormat::Cp(2));
    println!(
        "SRP collisions out of 8 hashes: cos=0.95 {} vs cos=0 {}",
        collide(&cp_srp.hash(&sim_x), &cp_srp.hash(&sim_y)),
        collide(&cp_srp.hash(&dis_x), &cp_srp.hash(&dis_y)),
    );
    Ok(())
}
