//! Near-duplicate image detection with CP-SRP — the paper's §1 motivating
//! application (near-duplicate detection over multidimensional data).
//!
//! Procedural "image patch" tensors (height × width × band) are generated in
//! groups of near-duplicates; a CP-SRP multi-table index must cluster them
//! back together without ever materializing a d^N projection vector.
//!
//! Run: `cargo run --release --example near_duplicate_images`

use tensor_lsh::prelude::*;
use tensor_lsh::workload::image_patches;

fn main() -> tensor_lsh::Result<()> {
    let (side, bands) = (24usize, 3usize);
    let dims = vec![side, side, bands];
    let (n_groups, dups) = (60usize, 5usize);
    let mut rng = Rng::new(2024);
    let (items, labels) = image_patches(&mut rng, n_groups, dups, side, bands, 0.15);
    println!(
        "corpus: {} patches ({} groups × {} near-duplicates), {}×{}×{}",
        items.len(),
        n_groups,
        dups,
        side,
        side,
        bands
    );

    // One declarative spec: CP-SRP, rank 8, K=12, L=8 tables, 2 probes.
    let spec = LshSpec::cosine(FamilyKind::Cp, dims, 8, 12, 8)
        .with_probes(2)
        .with_seed(7, 1);
    let index = IndexBuilder::new(spec).build_with(items)?;

    // For every patch, retrieve its nearest neighbors (excluding itself)
    // and check they come from the same duplicate group. The response
    // stats give the candidate counts directly — no second probing pass.
    let opts = QueryOpts::top_k(dups);
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut candidates = 0usize;
    for id in 0..index.len() {
        let resp = index.query_with(index.item(id), &opts)?;
        candidates += resp.stats.candidates_generated;
        for h in resp.hits.iter().filter(|h| h.id != id) {
            total += 1;
            if labels[h.id] == labels[id] {
                correct += 1;
            }
        }
    }
    let precision = correct as f64 / total as f64;
    println!(
        "duplicate-retrieval precision: {:.3} ({} / {} neighbor slots)",
        precision, correct, total
    );
    println!(
        "mean candidates/query: {:.1} of {} items ({:.1}% scanned)",
        candidates as f64 / index.len() as f64,
        index.len(),
        100.0 * candidates as f64 / (index.len() * index.len()) as f64
    );
    assert!(precision > 0.8, "near-duplicate precision collapsed");
    Ok(())
}
