//! END-TO-END DRIVER: the full three-layer stack on a real small workload.
//!
//! Proves all layers compose (recorded in EXPERIMENTS.md):
//!
//! 1. **L1/L2 (build time)** — `make artifacts` lowered the Pallas CP-SRP
//!    kernel + JAX hash pipeline to `artifacts/cp_srp.hlo.txt`.
//! 2. **Runtime** — this binary loads that HLO via PJRT (`PjrtEngine`),
//!    bulk-hashes a 10 000-tensor CP corpus through it (one execution per
//!    64-query batch yields all K=64 codes, banded into 8 table
//!    signatures), and builds the multi-table LSH index.
//! 3. **L3** — the coordinator serves a 2 000-query Zipf trace with dynamic
//!    batching, hashing queries through the same PJRT artifact. Two phases:
//!    a *flood* phase (throughput) and a *paced* phase (honest latency
//!    percentiles at ~50% of measured capacity), plus recall@10 vs exact.
//!
//! Run: `make artifacts && cargo run --release --example e2e_serving`

// Not the precision-audited hash path: example scaffolding on small bounded values.
#![allow(clippy::cast_possible_truncation)]

use std::sync::Arc;
use std::time::{Duration, Instant};
use tensor_lsh::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, HashBackend, PjrtServingParams, QueryRequest,
};
use tensor_lsh::index::{recall_at_k, signature, ShardedLshIndex};
use tensor_lsh::lsh::{FamilyKind, LshSpec};
use tensor_lsh::projection::CpRademacher;
use tensor_lsh::rng::Rng;
use tensor_lsh::runtime::{find_artifact_dir, PjrtEngine};
use tensor_lsh::tensor::{AnyTensor, CpTensor};
use tensor_lsh::workload::zipf_trace;

const N_ITEMS: usize = 10_000;
const N_QUERIES: usize = 2_000;
const BANDS: usize = 8; // K=64 codes → 8 tables × 8 codes
const SHARDS: usize = 8; // serving index shards (re-rank fan-out width)
const TOP_K: usize = 10;
const SEED: u64 = 20240710;

fn pjrt_params(dir: std::path::PathBuf, bank: CpRademacher) -> HashBackend {
    HashBackend::Pjrt(PjrtServingParams {
        artifact_dir: dir,
        artifact: "cp_srp".into(),
        bank,
        bands: BANDS,
        e2lsh: None,
    })
}

fn main() -> tensor_lsh::Result<()> {
    println!("=== tensor-lsh end-to-end serving driver ===\n");
    let dir = find_artifact_dir(None).expect("artifacts/ missing — run `make artifacts`");
    let mut engine = PjrtEngine::new(&dir)?;
    let cfg = engine.manifest().config.clone();
    let dims = cfg.dims();
    let band_k = cfg.k / BANDS;
    println!(
        "artifacts: {} (platform {}), shape {}^{} rank_in={} K={} batch={} → {} tables × {} codes",
        dir.display(),
        engine.platform(),
        cfg.d,
        cfg.n_modes,
        cfg.rank_in,
        cfg.k,
        cfg.batch,
        BANDS,
        band_k
    );

    // ---- corpus: 10k clustered CP tensors at the artifact shape ----------
    let t0 = Instant::now();
    let mut rng = Rng::derive(SEED, &[1]);
    let n_clusters = 100;
    let half = cfg.rank_in / 2;
    let centroids: Vec<CpTensor> = (0..n_clusters)
        .map(|_| {
            let mut c = CpTensor::random_gaussian(&mut rng, &dims, half);
            let n = c.frob_norm().max(1e-30);
            c.scale = (1.0 / n) as f32;
            c
        })
        .collect();
    let items: Vec<CpTensor> = (0..N_ITEMS)
        .map(|_| {
            let c = rng.below(n_clusters);
            let z = CpTensor::random_gaussian(&mut rng, &dims, half);
            let zn = z.frob_norm().max(1e-30);
            centroids[c]
                .add_scaled(1.0, &z, (0.35 / zn) as f32)
                .expect("same dims")
        })
        .collect();
    println!(
        "corpus: {} CP tensors (rank {}) generated in {:.2}s",
        N_ITEMS,
        items[0].rank(),
        t0.elapsed().as_secs_f64()
    );

    // ---- one banded spec: a K-wide bank sliced into table families -------
    // The spec expresses the artifact's layout declaratively: K/BANDS codes
    // per table, all slices of one bank seeded at SEED — the same bank the
    // PJRT executor projects with, so both paths bucket identically.
    let mut lsh_spec =
        LshSpec::cosine(FamilyKind::Cp, dims.clone(), cfg.rank_proj, band_k, BANDS)
            .with_banded(true)
            .with_seed(SEED, 0);
    lsh_spec.serving.shards = SHARDS;
    let bank: CpRademacher = lsh_spec.cp_bank()?;

    // ---- bulk index build through the PJRT artifact ----------------------
    let t0 = Instant::now();
    let index = ShardedLshIndex::from_spec(&lsh_spec)?;
    let mut start = 0;
    while start < items.len() {
        let end = (start + cfg.batch).min(items.len());
        let codes = engine.hash_cp("cp_srp", &items[start..end], &bank, None)?;
        for (off, row) in codes.iter().enumerate() {
            let sigs: Vec<u64> = (0..BANDS)
                .map(|b| signature(&row[b * band_k..(b + 1) * band_k]))
                .collect();
            index.insert_with_signatures(AnyTensor::Cp(items[start + off].clone()), &sigs);
        }
        start = end;
    }
    let index = Arc::new(index);
    let build_s = t0.elapsed().as_secs_f64();
    println!(
        "index: {} items × {} tables × {} shards hashed via PJRT + inserted in {:.2}s ({:.0} items/s)",
        index.len(),
        BANDS,
        SHARDS,
        build_s,
        N_ITEMS as f64 / build_s
    );
    for (t, (mean, max)) in index.occupancy().iter().enumerate().take(2) {
        println!("  table {t}: mean bucket {mean:.1}, max {max}");
    }

    // ---- query trace (Zipf over corpus; rank matches the artifact) -------
    let mut rng_q = Rng::derive(SEED, &[2]);
    let trace = zipf_trace(&mut rng_q, N_ITEMS, N_QUERIES, 1.1);
    let queries: Vec<QueryRequest> = trace
        .iter()
        .enumerate()
        .map(|(i, &id)| QueryRequest::new(i as u64, AnyTensor::Cp(items[id].clone()), TOP_K))
        .collect();

    // ---- phase 1: flood (throughput) --------------------------------------
    let ccfg = || CoordinatorConfig {
        n_workers: 4,
        batcher: BatcherConfig {
            max_batch: cfg.batch,
            max_wait: Duration::from_micros(300),
        },
    };
    let t0 = Instant::now();
    let (responses, snap) = Coordinator::serve_trace(
        Arc::clone(&index),
        ccfg(),
        pjrt_params(dir.clone(), bank.clone()),
        queries.clone(),
    )?;
    let flood_s = t0.elapsed().as_secs_f64();
    let pjrt_qps = responses.len() as f64 / flood_s;
    println!("\n--- phase 1: flood, PJRT hash path (throughput) ---");
    println!("queries: {} in {:.2}s → {:.0} QPS sustained", responses.len(), flood_s, pjrt_qps);
    println!("{snap}  (latency here includes queue wait — see paced phase)");

    // ---- recall vs exact ground truth on a sample -------------------------
    let sample = 50usize;
    let mut recall_sum = 0.0;
    for r in responses.iter().take(sample) {
        let exact = index.exact_search(&queries[r.id as usize].query.tensor, TOP_K)?;
        recall_sum += recall_at_k(&r.results, &exact);
    }
    let recall = recall_sum / sample as f64;
    println!("recall@{TOP_K} (sample of {sample}): {recall:.3}");

    // ---- phase 2: paced (honest latency) ----------------------------------
    // Latency is measured inside the coordinator (submit → re-rank done),
    // so pacing the submissions gives honest per-query latency; responses
    // accumulate in the (unbounded) output channel and are drained after.
    let paced_n = 500usize;
    let pace = Duration::from_secs_f64(1.0 / (pjrt_qps * 0.5)); // 50% load
    let coord = Coordinator::start(
        Arc::clone(&index),
        ccfg(),
        pjrt_params(dir.clone(), bank.clone()),
    );
    for q in queries.iter().take(paced_n) {
        coord.submit(q.clone())?;
        std::thread::sleep(pace);
    }
    let mut received = 0usize;
    for _ in 0..paced_n {
        match coord.recv() {
            Some(Ok(_)) => received += 1,
            Some(Err(e)) => return Err(e),
            None => break,
        }
    }
    let snap_paced = coord.shutdown();
    println!("\n--- phase 2: paced at ~50% capacity, PJRT hash path (latency) ---");
    println!("queries: {received} at {:.0} QPS offered", 1.0 / pace.as_secs_f64());
    println!("{snap_paced}");

    // ---- native backend comparison ----------------------------------------
    let t0 = Instant::now();
    let (responses_n, snap_n) =
        Coordinator::serve_trace(Arc::clone(&index), ccfg(), HashBackend::Native, queries)?;
    let native_s = t0.elapsed().as_secs_f64();
    println!("\n--- flood, native hash path (comparison) ---");
    println!(
        "queries: {} in {:.2}s → {:.0} QPS sustained",
        responses_n.len(),
        native_s,
        responses_n.len() as f64 / native_s
    );
    println!("{snap_n}");

    assert!(recall > 0.6, "e2e recall too low: {recall}");
    println!("\nE2E OK: three layers composed (Pallas kernel → HLO → PJRT → coordinator)");
    Ok(())
}
