//! Euclidean ANN over synthetic EEG epochs in TT format with TT-E2LSH —
//! the paper's §1 neuroscience motivation (tensor data that is natively
//! low-rank along channel × time × band) — with K and L chosen by the
//! spec's planner from the collision-probability theory.
//!
//! Run: `cargo run --release --example eeg_similarity`

use tensor_lsh::lsh::validity_report;
use tensor_lsh::prelude::*;
use tensor_lsh::workload::eeg_epochs;

fn main() -> Result<()> {
    let (channels, time, bands) = (16usize, 64usize, 4usize);
    let dims = vec![channels, time, bands];
    let mut rng = Rng::new(31);
    let items = eeg_epochs(&mut rng, 1200, channels, time, bands, 3);
    println!(
        "corpus: {} EEG epochs ({}ch × {} samples × {} bands), TT rank 3",
        items.len(),
        channels,
        time,
        bands
    );
    let rep = validity_report(&dims, 6);
    println!(
        "validity ratios at projection rank 6: cp={:.3} tt={:.3}",
        rep.cp_ratio, rep.tt_ratio
    );

    // Ask the planner for (K, L): unit-norm epochs put near pairs at
    // r₁ ≈ 0.5; plan against far pairs at c·r₁ = 1.5 with a 20% failure
    // budget. (`planned()` would additionally gate on the validity report —
    // at this small shape the TT ratio printed above is outside the
    // asymptotic regime, so we take the plan's K/L and report the ratio
    // honestly instead.)
    let spec = LshSpec::euclidean(FamilyKind::Tt, dims.clone(), 6, 6, 10, 2.0).with_seed(17, 1);
    let plan = spec.plan(items.len(), 0.5, 3.0, 0.2)?;
    println!(
        "planned from theory: K={}, L={} (ρ={:.3}, p1={:.3}, p2={:.3}, recall ≥ {:.2})",
        plan.k, plan.l, plan.rho, plan.p1, plan.p2, plan.recall_bound
    );
    let spec = spec.with_k(plan.k).with_tables(plan.l);

    let index = IndexBuilder::new(spec).build_with(items)?;

    // One QueryOpts drives every query; the per-response stats report the
    // candidate workload the planner's (K, L) actually produces.
    let opts = QueryOpts::top_k(10);
    let mut recall_sum = 0.0;
    let mut cand_sum = 0usize;
    let n_q = 50;
    for _ in 0..n_q {
        let qid = rng.below(index.len());
        let q = index.item(qid).clone();
        let approx = index.query_with(&q, &opts)?;
        let exact = index.exact_search(&q, 10)?;
        recall_sum += tensor_lsh::index::recall_at_k(&approx.hits, &exact);
        cand_sum += approx.stats.candidates_examined;
    }
    println!(
        "TT-E2LSH recall@10 over {n_q} queries: {:.3} ({:.1} candidates/query)",
        recall_sum / n_q as f64,
        cand_sum as f64 / n_q as f64
    );
    for (t, (mean, max)) in index.occupancy().iter().enumerate().take(3) {
        println!("table {t}: mean bucket {mean:.1}, max {max}");
    }
    Ok(())
}
