//! Euclidean ANN over synthetic EEG epochs in TT format with TT-E2LSH —
//! the paper's §1 neuroscience motivation (tensor data that is natively
//! low-rank along channel × time × band).
//!
//! Run: `cargo run --release --example eeg_similarity`

use std::sync::Arc;
use tensor_lsh::index::{recall_at_k, IndexConfig, LshIndex, Metric};
use tensor_lsh::lsh::{validity_report, HashFamily, TtE2lsh, TtE2lshConfig};
use tensor_lsh::rng::Rng;
use tensor_lsh::workload::eeg_epochs;

fn main() -> tensor_lsh::Result<()> {
    let (channels, time, bands) = (16usize, 64usize, 4usize);
    let dims = vec![channels, time, bands];
    let mut rng = Rng::new(31);
    let items = eeg_epochs(&mut rng, 1200, channels, time, bands, 3);
    println!(
        "corpus: {} EEG epochs ({}ch × {} samples × {} bands), TT rank 3",
        items.len(),
        channels,
        time,
        bands
    );
    let rep = validity_report(&dims, 6);
    println!(
        "validity ratios at projection rank 6: cp={:.3} tt={:.3}",
        rep.cp_ratio, rep.tt_ratio
    );

    let cfg = IndexConfig {
        family_builder: {
            let dims = dims.clone();
            Arc::new(move |t| {
                Arc::new(TtE2lsh::new(TtE2lshConfig {
                    dims: dims.clone(),
                    rank: 6,
                    k: 6,
                    w: 2.0, // unit-norm epochs: near pairs at r≈0.5 ⇒ p₁≈0.8
                    seed: 17 + t as u64,
                })) as Arc<dyn HashFamily>
            })
        },
        n_tables: 10,
        metric: Metric::Euclidean,
        probes: 0,
    };
    let index = LshIndex::build(&cfg, items)?;

    let mut recall_sum = 0.0;
    let n_q = 50;
    for _ in 0..n_q {
        let qid = rng.below(index.len());
        let q = index.item(qid).clone();
        let approx = index.search(&q, 10)?;
        let exact = index.exact_search(&q, 10)?;
        recall_sum += recall_at_k(&approx, &exact);
    }
    println!("TT-E2LSH recall@10 over {n_q} queries: {:.3}", recall_sum / n_q as f64);
    for (t, (mean, max)) in index.occupancy().iter().enumerate().take(3) {
        println!("table {t}: mean bucket {mean:.1}, max {max}");
    }
    Ok(())
}
