"""Layer-2 JAX model: the six tensorized LSH hash families.

Composes the Layer-1 Pallas projection kernels with the E2LSH / SRP
discretizers into full hash pipelines

    (input tensors, projection parameters, b, w)  ->  (B, K) int32 codes

for CP-E2LSH (Def. 10), TT-E2LSH (Def. 11), CP-SRP (Def. 12), TT-SRP
(Def. 13) and the two naive baselines (reshape + E2LSH [11] / SRP [6]).

Build-time only: these functions are lowered once by ``compile.aot`` to HLO
text and executed from the Rust coordinator via PJRT. Python is never on the
request path.
"""

import jax.numpy as jnp

from .kernels import cp_project, tt_project, dense_project


# ---------------------------------------------------------------------------
# discretizers
# ---------------------------------------------------------------------------

def e2lsh_codes(z, b, w):
    """E2LSH discretization: floor((z + b) / w) (Eq. 3.3 / 4.1 / 4.20).

    z: (B, K) projections; b: (K,) uniform offsets in [0, w); w: scalar ().
    Returns (B, K) int32 hash codes (can be negative).
    """
    return jnp.floor((z + b[None, :]) / w).astype(jnp.int32)


def srp_codes(z):
    """SRP discretization: sign (Eq. 3.1 / 4.34 / 4.61) mapped to {0, 1}."""
    return (z > 0.0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# hash families (projection kernel + discretizer)
# ---------------------------------------------------------------------------

def cp_e2lsh(x_factors, a_factors, b, w, interpret=True):
    """CP-E2LSH (Definition 10): g(X) = floor((<P, X> + b) / w)."""
    z = cp_project(x_factors, a_factors, interpret=interpret)
    return e2lsh_codes(z, b, w)


def tt_e2lsh(x_cores, g_cores, b, w, interpret=True):
    """TT-E2LSH (Definition 11): g~(X) = floor((<T, X> + b) / w)."""
    z = tt_project(x_cores, g_cores, interpret=interpret)
    return e2lsh_codes(z, b, w)


def cp_srp(x_factors, a_factors, interpret=True):
    """CP-SRP (Definition 12): h(X) = sgn(<P, X>)."""
    return srp_codes(cp_project(x_factors, a_factors, interpret=interpret))


def tt_srp(x_cores, g_cores, interpret=True):
    """TT-SRP (Definition 13): h~(X) = sgn(<T, X>)."""
    return srp_codes(tt_project(x_cores, g_cores, interpret=interpret))


def naive_e2lsh(x_flat, proj, b, w, interpret=True):
    """Naive baseline: reshape + E2LSH [11] on the d^N-vector."""
    z = dense_project(x_flat, proj, interpret=interpret)
    return e2lsh_codes(z, b, w)


def naive_srp(x_flat, proj, interpret=True):
    """Naive baseline: reshape + SRP [6] on the d^N-vector."""
    return srp_codes(dense_project(x_flat, proj, interpret=interpret))


# Projection-only entry points (the coordinator sometimes wants raw z, e.g.
# for multiprobe ranking which needs distances to bucket boundaries).

def cp_project_z(x_factors, a_factors, interpret=True):
    return cp_project(x_factors, a_factors, interpret=interpret)


def tt_project_z(x_cores, g_cores, interpret=True):
    return tt_project(x_cores, g_cores, interpret=interpret)
