"""AOT export: lower the L2 hash pipelines to HLO text artifacts.

Emits one ``artifacts/<name>.hlo.txt`` per hash family at the canonical
serving shapes plus ``artifacts/manifest.json`` describing each artifact's
inputs/outputs so the Rust runtime can load and drive them without any
Python at request time.

HLO *text* is the interchange format — NOT ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Canonical serving configuration. The Rust side reads these from the
# manifest; changing them here and re-running `make artifacts` is the only
# coordination needed.
CONFIG = {
    "n_modes": 3,
    "d": 32,          # per-mode dimension
    "rank_in": 8,     # Rhat: input CP/TT rank
    "rank_proj": 8,   # R: projection CP/TT rank
    "k": 64,          # hashes per table signature
    "batch": 64,      # queries per PJRT execution
}


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _cp_factor_specs(batch_or_k, n, d, rank):
    return [_spec((batch_or_k, d, rank)) for _ in range(n)]


def _tt_core_specs(batch_or_k, n, d, rank):
    specs = []
    for i in range(n):
        rp = 1 if i == 0 else rank
        rn = 1 if i == n - 1 else rank
        specs.append(_spec((batch_or_k, rp, d, rn)))
    return specs


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_entries(cfg):
    """Returns [(name, jitted_fn, example_specs, input_desc, output_desc)]."""
    n, d = cfg["n_modes"], cfg["d"]
    rin, rpj = cfg["rank_in"], cfg["rank_proj"]
    k, batch = cfg["k"], cfg["batch"]
    dn = d ** n

    x_cp = _cp_factor_specs(batch, n, d, rin)
    a_cp = _cp_factor_specs(k, n, d, rpj)
    x_tt = _tt_core_specs(batch, n, d, rin)
    g_tt = _tt_core_specs(k, n, d, rpj)
    b_spec = _spec((k,))
    w_spec = _spec(())
    x_flat = _spec((batch, dn))
    p_dense = _spec((k, dn))

    def shapes(specs):
        return [list(s.shape) for s in specs]

    entries = []
    entries.append((
        "cp_e2lsh",
        lambda *a: (model.cp_e2lsh(list(a[:n]), list(a[n:2 * n]), a[2 * n], a[2 * n + 1]),),
        x_cp + a_cp + [b_spec, w_spec],
        {"x_factors": shapes(x_cp), "a_factors": shapes(a_cp), "b": [k], "w": []},
        {"codes": [batch, k], "dtype": "i32"},
    ))
    entries.append((
        "tt_e2lsh",
        lambda *a: (model.tt_e2lsh(list(a[:n]), list(a[n:2 * n]), a[2 * n], a[2 * n + 1]),),
        x_tt + g_tt + [b_spec, w_spec],
        {"x_cores": shapes(x_tt), "g_cores": shapes(g_tt), "b": [k], "w": []},
        {"codes": [batch, k], "dtype": "i32"},
    ))
    entries.append((
        "cp_srp",
        lambda *a: (model.cp_srp(list(a[:n]), list(a[n:2 * n])),),
        x_cp + a_cp,
        {"x_factors": shapes(x_cp), "a_factors": shapes(a_cp)},
        {"codes": [batch, k], "dtype": "i32"},
    ))
    entries.append((
        "tt_srp",
        lambda *a: (model.tt_srp(list(a[:n]), list(a[n:2 * n])),),
        x_tt + g_tt,
        {"x_cores": shapes(x_tt), "g_cores": shapes(g_tt)},
        {"codes": [batch, k], "dtype": "i32"},
    ))
    entries.append((
        "naive_e2lsh",
        lambda x, p, b, w: (model.naive_e2lsh(x, p, b, w),),
        [x_flat, p_dense, b_spec, w_spec],
        {"x_flat": [list(x_flat.shape)], "proj": [list(p_dense.shape)], "b": [k], "w": []},
        {"codes": [batch, k], "dtype": "i32"},
    ))
    entries.append((
        "naive_srp",
        lambda x, p: (model.naive_srp(x, p),),
        [x_flat, p_dense],
        {"x_flat": [list(x_flat.shape)], "proj": [list(p_dense.shape)]},
        {"codes": [batch, k], "dtype": "i32"},
    ))
    entries.append((
        "cp_project",
        lambda *a: (model.cp_project_z(list(a[:n]), list(a[n:2 * n])),),
        x_cp + a_cp,
        {"x_factors": shapes(x_cp), "a_factors": shapes(a_cp)},
        {"z": [batch, k], "dtype": "f32"},
    ))
    return entries


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"config": CONFIG, "artifacts": {}}
    for name, fn, specs, in_desc, out_desc in build_entries(CONFIG):
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": in_desc,
            "input_order": [list(s.shape) for s in specs],
            "output": out_desc,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
