"""Pallas kernel: the naive baseline — dense Gaussian projection.

Computes  z[b, k] = <p_k, x_b>  for reshaped tensors x_b in R^D
(D = prod(d_n)) and dense Gaussian rows p_k — the O(d^N)-per-hash naive
method of Tables 1 and 2 (reshape + E2LSH / SRP). One (K, D) @ (D,) matvec
per grid step; the projection matrix is the whole working set, which is the
point: it does not fit fast memory once d^N grows. interpret=True for CPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_kernel(x_ref, p_ref, o_ref):
    x = x_ref[0]  # (D,)
    p = p_ref[...]  # (K, D)
    o_ref[0, :] = jnp.dot(p, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dense_project(x_flat, proj, interpret: bool = True):
    """Project flattened dense inputs onto K dense Gaussian vectors.

    Args:
      x_flat: (B, D) float32 — inputs reshaped to vectors.
      proj:   (K, D) float32 — N(0,1) projection rows (pre-scaled).
    Returns:
      (B, K) float32 projections.
    """
    b_dim, d_dim = x_flat.shape
    k_dim = proj.shape[0]
    return pl.pallas_call(
        _dense_kernel,
        grid=(b_dim,),
        in_specs=[
            pl.BlockSpec((1, d_dim), lambda b: (b, 0)),
            pl.BlockSpec((k_dim, d_dim), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k_dim), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((b_dim, k_dim), jnp.float32),
        interpret=interpret,
    )(x_flat, proj)
