"""Pallas kernel: batched TT x TT inner products (transfer-matrix sweep).

Computes  z[b, k] = (1/sqrt(R^{N-1})) * <T_k, X_b>  where

  T_k = <<G1[k], ..., GN[k]>>   (TT rank-R projection tensor, Definition 7)
  X_b = <<X1[b], ..., XN[b]>>   (TT rank-Rhat input tensor,   Definition 5)

via the standard transfer-matrix contraction: maintain M in R^{rhat x r},

  M_1[a', b'] = sum_i X1[0, i, a'] * G1[0, i, b']
  M_n[a', b'] = sum_{a, b, i} M_{n-1}[a, b] * Xn[a, i, a'] * Gn[b, i, b']

which costs O(d r rhat (r + rhat)) per mode — the O(N d max{R,Rhat}^3)
algorithm of Remark 2 / Table 1. The K transfer matrices live in one
(K, rhat, r) VMEM-resident accumulator; contraction order does the
(d * rhat, r) matmuls on the MXU. interpret=True for CPU.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tt_kernel(*refs, n_modes: int):
    # refs = x_1..x_N (each (1, rp, d_n, rn)), g_1..g_N (each (K, rp, d_n, rn)),
    #        out (1, K)
    x_refs = refs[:n_modes]
    g_refs = refs[n_modes : 2 * n_modes]
    o_ref = refs[2 * n_modes]
    k_dim = g_refs[0].shape[0]
    r = max(g.shape[3] for g in g_refs)  # proj TT-rank R (internal bond)
    # M[k, a, b]: transfer matrix between input bond a and projection bond b.
    m = jnp.ones((k_dim, 1, 1), dtype=jnp.float32)
    for n in range(n_modes):
        x = x_refs[n][0]  # (rp_x, d, rn_x)
        g = g_refs[n][...]  # (K, rp_g, d, rn_g)
        # tmp[k, i, b, a'] = sum_a m[k, a, b] * x[a, i, a']
        tmp = jnp.einsum("kab,aic->kicb", m, x, preferred_element_type=jnp.float32)
        # m'[k, a', b'] = sum_{i, b} tmp[k, i, b, a'] * g[k, b, i, b']
        m = jnp.einsum("kicb,kbid->kcd", tmp, g, preferred_element_type=jnp.float32)
    z = m[:, 0, 0] * (1.0 / math.sqrt(float(r) ** (n_modes - 1)))
    o_ref[0, :] = z


@functools.partial(jax.jit, static_argnames=("interpret",))
def tt_project(x_cores, g_cores, interpret: bool = True):
    """Project TT-format inputs onto K TT-Rademacher tensors.

    Args:
      x_cores: list of N arrays (B, rp, d_n, rn) with r_0 = r_N = 1.
      g_cores: list of N arrays (K, rp, d_n, rn) — unscaled (+/-1) projection
        cores; the 1/sqrt(R^{N-1}) scale of Definition 7 is applied here.
    Returns:
      (B, K) float32 projections z[b, k] = <T_k, X_b>.
    """
    n_modes = len(x_cores)
    b_dim = x_cores[0].shape[0]
    k_dim = g_cores[0].shape[0]
    in_specs = [
        pl.BlockSpec((1,) + x.shape[1:], lambda b: (b, 0, 0, 0)) for x in x_cores
    ] + [pl.BlockSpec(g.shape, lambda b: (0, 0, 0, 0)) for g in g_cores]
    out_spec = pl.BlockSpec((1, k_dim), lambda b: (b, 0))
    kernel = functools.partial(_tt_kernel, n_modes=n_modes)
    return pl.pallas_call(
        kernel,
        grid=(b_dim,),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b_dim, k_dim), jnp.float32),
        interpret=interpret,
    )(*x_cores, *g_cores)
