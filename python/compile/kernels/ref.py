"""Pure-jnp oracles for the Pallas kernels.

Two tiers:
  * ``*_materialize``: reconstruct the full dense tensors and take the exact
    inner product — the ground truth definition, O(d^N), used only in tests.
  * ``*_project_ref``: the same efficient contraction as the kernels but in
    plain jnp (no pallas) — structural cross-check and the L2 fallback.
"""

import math

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# dense materialization
# ---------------------------------------------------------------------------

def cp_materialize(factors):
    """Dense tensor from CP factors. factors: list of N arrays (d_n, R)."""
    r = factors[0].shape[1]
    shape = tuple(f.shape[0] for f in factors)
    out = jnp.zeros(shape, dtype=jnp.float32)
    for s in range(r):
        term = factors[0][:, s]
        for f in factors[1:]:
            term = jnp.tensordot(term, f[:, s], axes=0)
        out = out + term
    return out


def tt_materialize(cores):
    """Dense tensor from TT cores. cores: list of N arrays (rp, d_n, rn)."""
    out = cores[0]  # (1, d1, r1)
    for core in cores[1:]:
        # (1, d1..dk, r) x (r, d_{k+1}, r') -> (1, d1..d_{k+1}, r')
        out = jnp.tensordot(out, core, axes=([out.ndim - 1], [0]))
    return out[0, ..., 0]


# ---------------------------------------------------------------------------
# exact (materializing) oracles
# ---------------------------------------------------------------------------

def cp_project_materialize(x_factors, a_factors):
    """Exact z[b,k] by materializing both CP tensors."""
    b_dim = x_factors[0].shape[0]
    k_dim = a_factors[0].shape[0]
    r = a_factors[0].shape[2]
    out = []
    for b in range(b_dim):
        xb = cp_materialize([f[b] for f in x_factors])
        row = []
        for k in range(k_dim):
            pk = cp_materialize([a[k] for a in a_factors]) / math.sqrt(r)
            row.append(jnp.sum(pk * xb))
        out.append(jnp.stack(row))
    return jnp.stack(out)


def tt_project_materialize(x_cores, g_cores):
    """Exact z[b,k] by materializing both TT tensors."""
    b_dim = x_cores[0].shape[0]
    k_dim = g_cores[0].shape[0]
    n = len(g_cores)
    r = max(g.shape[3] for g in g_cores)
    scale = 1.0 / math.sqrt(float(r) ** (n - 1))
    out = []
    for b in range(b_dim):
        xb = tt_materialize([c[b] for c in x_cores])
        row = []
        for k in range(k_dim):
            tk = tt_materialize([g[k] for g in g_cores]) * scale
            row.append(jnp.sum(tk * xb))
        out.append(jnp.stack(row))
    return jnp.stack(out)


# ---------------------------------------------------------------------------
# efficient jnp references (same algorithm as kernels, no pallas)
# ---------------------------------------------------------------------------

def cp_project_ref(x_factors, a_factors):
    """Hadamard-of-Grams CP x CP projection in plain jnp."""
    k_dim = a_factors[0].shape[0]
    r = a_factors[0].shape[2]
    rhat = x_factors[0].shape[2]
    b_dim = x_factors[0].shape[0]
    acc = jnp.ones((b_dim, k_dim, r, rhat), dtype=jnp.float32)
    for x, a in zip(x_factors, a_factors):
        gram = jnp.einsum("kdr,bds->bkrs", a, x)
        acc = acc * gram
    return jnp.sum(acc, axis=(2, 3)) / math.sqrt(r)


def tt_project_ref(x_cores, g_cores):
    """Transfer-matrix TT x TT projection in plain jnp."""
    n = len(g_cores)
    b_dim = x_cores[0].shape[0]
    k_dim = g_cores[0].shape[0]
    r = max(g.shape[3] for g in g_cores)
    m = jnp.ones((b_dim, k_dim, 1, 1), dtype=jnp.float32)
    for x, g in zip(x_cores, g_cores):
        tmp = jnp.einsum("BKab,Baic->BKicb", m, x)
        m = jnp.einsum("BKicb,Kbid->BKcd", tmp, g)
    scale = 1.0 / math.sqrt(float(r) ** (n - 1))
    return m[:, :, 0, 0] * scale


def dense_project_ref(x_flat, proj):
    """Naive dense projection in plain jnp."""
    return x_flat @ proj.T
