"""Pallas kernel: batched CP x CP inner products (Hadamard-of-Grams).

Computes  z[b, k] = (1/sqrt(R)) * <P_k, X_b>  where

  P_k = [[A1[k], ..., AN[k]]]   (CP rank-R projection tensor, Definition 6)
  X_b = [[X1[b], ..., XN[b]]]   (CP rank-Rhat input tensor,   Definition 4)

using the identity

  <P_k, X_b> = sum_{r,s}  prod_n  (An[k]^T Xn[b])[r, s]

i.e. a Hadamard product of per-mode Gram matrices followed by a full
reduction — the O(N d max{R,Rhat}^2) algorithm of Remark 1 / Table 1.

TPU mapping (see DESIGN.md §Hardware-Adaptation): per grid step (one input
tensor b) the kernel performs one fattened matmul per mode,
(K*R, d) @ (d, Rhat) — the MXU-friendly core op — and keeps the (K, R, Rhat)
Hadamard accumulator resident in VMEM across modes. interpret=True for CPU.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cp_kernel(*refs, n_modes: int):
    # refs = x_1..x_N (each (1, d_n, Rhat)), a_1..a_N (each (K, d_n, R)), out (1, K)
    x_refs = refs[:n_modes]
    a_refs = refs[n_modes : 2 * n_modes]
    o_ref = refs[2 * n_modes]
    a0 = a_refs[0]
    k_dim, _, r = a0.shape
    rhat = x_refs[0].shape[2]
    acc = jnp.ones((k_dim, r, rhat), dtype=jnp.float32)
    for n in range(n_modes):
        x = x_refs[n][0]  # (d_n, Rhat)
        a = a_refs[n][...]  # (K, d_n, R)
        # Fattened MXU matmul: (K*R, d) @ (d, Rhat) -> (K*R, Rhat)
        d_n = a.shape[1]
        a2 = jnp.transpose(a, (0, 2, 1)).reshape(k_dim * r, d_n)
        gram = jnp.dot(a2, x, preferred_element_type=jnp.float32)
        acc = acc * gram.reshape(k_dim, r, rhat)
    z = jnp.sum(acc, axis=(1, 2)) * (1.0 / math.sqrt(r))
    o_ref[0, :] = z


@functools.partial(jax.jit, static_argnames=("interpret",))
def cp_project(x_factors, a_factors, interpret: bool = True):
    """Project CP-format inputs onto K CP-Rademacher tensors.

    Args:
      x_factors: list of N arrays (B, d_n, Rhat) — input CP factors.
      a_factors: list of N arrays (K, d_n, R) — unscaled (+/-1) projection
        factors; the 1/sqrt(R) scale of Definition 6 is applied here.
    Returns:
      (B, K) float32 projections z[b, k] = <P_k, X_b>.
    """
    n_modes = len(x_factors)
    b_dim = x_factors[0].shape[0]
    k_dim = a_factors[0].shape[0]
    in_specs = [
        pl.BlockSpec((1,) + x.shape[1:], lambda b, _n=None: (b, 0, 0))
        for x in x_factors
    ] + [pl.BlockSpec(a.shape, lambda b: (0, 0, 0)) for a in a_factors]
    out_spec = pl.BlockSpec((1, k_dim), lambda b: (b, 0))
    kernel = functools.partial(_cp_kernel, n_modes=n_modes)
    return pl.pallas_call(
        kernel,
        grid=(b_dim,),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b_dim, k_dim), jnp.float32),
        interpret=interpret,
    )(*x_factors, *a_factors)
