"""Layer-1 Pallas kernels for tensorized random-projection LSH.

Each kernel computes the batched inner product between input tensors and a
bank of K projection tensors — the compute hot-spot of CP-E2LSH / TT-E2LSH /
CP-SRP / TT-SRP (Verma & Pratap, 2024) — and runs under ``interpret=True``
so the lowered HLO executes on the CPU PJRT plugin (real-TPU lowering emits
Mosaic custom-calls the CPU client cannot run).

Conventions (all float32):
  - CP input factors:  list of N arrays, shape (B, d_n, Rhat)
  - CP proj factors:   list of N arrays, shape (K, d_n, R)    (raw +/-1 entries)
  - TT input cores:    list of N arrays, shape (B, rp, d_n, rn), r_0 = r_N = 1
  - TT proj cores:     list of N arrays, shape (K, rp, d_n, rn) (raw +/-1)
  - dense input:       (B, D) with D = prod(d_n); dense proj: (K, D)

The 1/sqrt(R) (CP, Definition 6) and 1/sqrt(R^{N-1}) (TT, Definition 7)
normalizations are applied *inside* the kernels, so callers pass unscaled
Rademacher factors.
"""

from .cp_inner import cp_project
from .tt_inner import tt_project
from .dense_inner import dense_project
from . import ref

__all__ = ["cp_project", "tt_project", "dense_project", "ref"]
