"""Build-time compile package: Pallas kernels (L1), JAX model (L2), AOT export."""
