"""AOT export tests: lowering round-trip, manifest integrity."""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def test_to_hlo_text_roundtrip_smoke():
    """Lowered HLO text must contain an ENTRY computation and parameters."""
    def fn(x):
        return (x * 2.0,)
    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "parameter(0)" in text


def test_build_entries_cover_all_families():
    names = {e[0] for e in aot.build_entries(aot.CONFIG)}
    assert names == {
        "cp_e2lsh", "tt_e2lsh", "cp_srp", "tt_srp",
        "naive_e2lsh", "naive_srp", "cp_project",
    }


def test_entry_specs_execute_and_match_ref():
    """Each AOT entry, called with random inputs at its exact specs, matches
    the pure-jnp reference — i.e. the lowered graph computes the model."""
    cfg = dict(aot.CONFIG)
    cfg.update(d=6, k=5, batch=3, rank_in=2, rank_proj=2)  # small for speed
    rng = np.random.default_rng(0)
    for name, fn, specs, _, _ in aot.build_entries(cfg):
        args = []
        for s in specs:
            if s.shape and s.shape[-1] != 0 and len(s.shape) >= 2:
                args.append(jnp.asarray(rng.normal(size=s.shape).astype(np.float32)))
            elif s.shape == ():
                args.append(jnp.asarray(np.float32(4.0)))
            else:
                args.append(jnp.asarray(rng.uniform(0, 4, size=s.shape).astype(np.float32)))
        out = np.asarray(fn(*args)[0])
        n = cfg["n_modes"]
        if name in ("cp_e2lsh", "cp_srp", "cp_project"):
            z = np.asarray(ref.cp_project_ref(list(args[:n]), list(args[n:2 * n])))
        elif name in ("tt_e2lsh", "tt_srp"):
            z = np.asarray(ref.tt_project_ref(list(args[:n]), list(args[n:2 * n])))
        else:
            z = np.asarray(ref.dense_project_ref(args[0], args[1]))
        if name.endswith("srp"):
            np.testing.assert_array_equal(out, (z > 0).astype(np.int32))
        elif name.endswith("e2lsh"):
            b, w = np.asarray(args[-2]), float(args[-1])
            np.testing.assert_array_equal(
                out, np.floor((z + b[None, :]) / w).astype(np.int32))
        else:
            np.testing.assert_allclose(out, z, rtol=2e-4, atol=2e-4)


def test_manifest_written_and_consistent(tmp_path):
    """End-to-end CLI run at tiny shapes writes artifacts + manifest."""
    env = dict(os.environ)
    code = (
        "import sys; sys.argv=['aot','--out-dir', r'%s','--only','cp_srp'];"
        "from compile import aot; aot.CONFIG.update(d=4,k=3,batch=2,rank_in=2,rank_proj=2);"
        "aot.main()" % tmp_path
    )
    subprocess.run([sys.executable, "-c", code],
                   cwd=os.path.join(os.path.dirname(__file__), ".."),
                   check=True, env=env)
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert "cp_srp" in manifest["artifacts"]
    entry = manifest["artifacts"]["cp_srp"]
    text = (tmp_path / entry["file"]).read_text()
    assert len(text) == entry["bytes"]
    assert "ENTRY" in text
