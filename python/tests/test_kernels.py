"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (N, B, K, d, Rhat, R); every draw asserts the
kernel, the efficient-jnp reference, and the exact materializing oracle all
agree to float32 tolerance.
"""

import math

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cp_project, tt_project, dense_project, ref

RTOL, ATOL = 2e-4, 2e-4


def _rng(seed):
    return np.random.default_rng(seed)


def _cp_inputs(rng, n, b, k, d, rhat, r):
    xf = [jnp.asarray(rng.normal(size=(b, d, rhat)).astype(np.float32)) for _ in range(n)]
    af = [jnp.asarray(rng.choice([-1.0, 1.0], size=(k, d, r)).astype(np.float32)) for _ in range(n)]
    return xf, af


def _tt_shapes(n, r):
    return [(1 if i == 0 else r, 1 if i == n - 1 else r) for i in range(n)]


def _tt_inputs(rng, n, b, k, d, rhat, r, rademacher_proj=True):
    xc = [jnp.asarray(rng.normal(size=(b, rp, d, rn)).astype(np.float32))
          for rp, rn in _tt_shapes(n, rhat)]
    if rademacher_proj:
        gc = [jnp.asarray(rng.choice([-1.0, 1.0], size=(k, rp, d, rn)).astype(np.float32))
              for rp, rn in _tt_shapes(n, r)]
    else:
        gc = [jnp.asarray(rng.normal(size=(k, rp, d, rn)).astype(np.float32))
              for rp, rn in _tt_shapes(n, r)]
    return xc, gc


shape_strategy = st.tuples(
    st.integers(2, 4),   # n modes
    st.integers(1, 4),   # batch
    st.integers(1, 6),   # k
    st.integers(2, 8),   # d
    st.integers(1, 4),   # rhat
    st.integers(1, 4),   # r
)


@settings(max_examples=25, deadline=None)
@given(shape=shape_strategy, seed=st.integers(0, 2**31 - 1))
def test_cp_kernel_matches_refs(shape, seed):
    n, b, k, d, rhat, r = shape
    xf, af = _cp_inputs(_rng(seed), n, b, k, d, rhat, r)
    z = np.asarray(cp_project(xf, af))
    z_ref = np.asarray(ref.cp_project_ref(xf, af))
    z_mat = np.asarray(ref.cp_project_materialize(xf, af))
    np.testing.assert_allclose(z, z_ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(z, z_mat, rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(shape=shape_strategy, seed=st.integers(0, 2**31 - 1))
def test_tt_kernel_matches_refs(shape, seed):
    n, b, k, d, rhat, r = shape
    xc, gc = _tt_inputs(_rng(seed), n, b, k, d, rhat, r)
    z = np.asarray(tt_project(xc, gc))
    z_ref = np.asarray(ref.tt_project_ref(xc, gc))
    z_mat = np.asarray(ref.tt_project_materialize(xc, gc))
    np.testing.assert_allclose(z, z_ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(z, z_mat, rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 5),
    k=st.integers(1, 8),
    dim=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_kernel_matches_ref(b, k, dim, seed):
    rng = _rng(seed)
    x = jnp.asarray(rng.normal(size=(b, dim)).astype(np.float32))
    p = jnp.asarray(rng.normal(size=(k, dim)).astype(np.float32))
    z = np.asarray(dense_project(x, p))
    np.testing.assert_allclose(
        z, np.asarray(ref.dense_project_ref(x, p)), rtol=RTOL, atol=ATOL
    )


def test_cp_scaling_is_inv_sqrt_r():
    """Doubling R with identical repeated factors scales z by sqrt(2)... i.e.
    the 1/sqrt(R) normalization of Definition 6 is really applied."""
    rng = _rng(7)
    n, b, k, d, rhat = 3, 2, 3, 5, 2
    xf, af1 = _cp_inputs(rng, n, b, k, d, rhat, 1)
    af2 = [jnp.concatenate([a, a], axis=2) for a in af1]  # rank 2, duplicated
    z1 = np.asarray(cp_project(xf, af1))
    z2 = np.asarray(cp_project(xf, af2))
    # sum doubles, scale is 1/sqrt(2) instead of 1 -> z2 = sqrt(2) z1
    np.testing.assert_allclose(z2, math.sqrt(2.0) * z1, rtol=1e-4, atol=1e-4)


def test_tt_gaussian_proj_also_supported():
    """TT kernel is distribution-agnostic (Gaussian cores, Definition 7 rem.)."""
    rng = _rng(11)
    xc, gc = _tt_inputs(rng, 3, 2, 3, 4, 2, 2, rademacher_proj=False)
    z = np.asarray(tt_project(xc, gc))
    z_mat = np.asarray(ref.tt_project_materialize(xc, gc))
    np.testing.assert_allclose(z, z_mat, rtol=RTOL, atol=ATOL)


def test_cp_inner_linearity():
    """<P, aX + bY> = a<P, X> + b<P, Y> — projections are linear maps."""
    rng = _rng(13)
    n, b, k, d, rhat, r = 3, 1, 4, 6, 2, 3
    xf, af = _cp_inputs(rng, n, b, k, d, rhat, r)
    yf, _ = _cp_inputs(rng, n, b, k, d, rhat, r)
    # CP sum: concatenate factor columns; scale one term's first factor.
    a, c = 0.7, -1.3
    sf = [jnp.concatenate([x * (a if i == 0 else 1.0), y * (c if i == 0 else 1.0)], axis=2)
          for i, (x, y) in enumerate(zip(xf, yf))]
    zs = np.asarray(cp_project(sf, af))
    zx = np.asarray(cp_project(xf, af))
    zy = np.asarray(cp_project(yf, af))
    np.testing.assert_allclose(zs, a * zx + c * zy, rtol=1e-3, atol=1e-3)
