"""L2 model tests: discretizers and full hash pipelines."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _rng(seed):
    return np.random.default_rng(seed)


def test_e2lsh_codes_floor_semantics():
    z = jnp.asarray([[-1.01, -0.5, 0.0, 0.49, 0.5, 3.99]], dtype=jnp.float32)
    b = jnp.zeros((6,), dtype=jnp.float32)
    w = jnp.asarray(1.0, dtype=jnp.float32)
    codes = np.asarray(model.e2lsh_codes(z, b, w))
    np.testing.assert_array_equal(codes, [[-2, -1, 0, 0, 0, 3]])


def test_e2lsh_codes_offset_and_width():
    z = jnp.asarray([[0.9, 1.1]], dtype=jnp.float32)
    b = jnp.asarray([0.2, 0.2], dtype=jnp.float32)
    w = jnp.asarray(0.5, dtype=jnp.float32)
    codes = np.asarray(model.e2lsh_codes(z, b, w))
    np.testing.assert_array_equal(codes, [[2, 2]])


def test_srp_codes_sign_semantics():
    z = jnp.asarray([[-3.0, -1e-9, 0.0, 1e-9, 5.0]], dtype=jnp.float32)
    codes = np.asarray(model.srp_codes(z))
    np.testing.assert_array_equal(codes, [[0, 0, 0, 1, 1]])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_cp_e2lsh_pipeline_matches_manual(seed):
    rng = _rng(seed)
    n, b_dim, k, d, rhat, r = 3, 2, 4, 5, 2, 3
    xf = [jnp.asarray(rng.normal(size=(b_dim, d, rhat)).astype(np.float32)) for _ in range(n)]
    af = [jnp.asarray(rng.choice([-1.0, 1.0], size=(k, d, r)).astype(np.float32)) for _ in range(n)]
    b = jnp.asarray(rng.uniform(0, 4.0, size=(k,)).astype(np.float32))
    w = jnp.asarray(np.float32(4.0))
    codes = np.asarray(model.cp_e2lsh(xf, af, b, w))
    z = np.asarray(ref.cp_project_ref(xf, af))
    manual = np.floor((z + np.asarray(b)[None, :]) / 4.0).astype(np.int32)
    np.testing.assert_array_equal(codes, manual)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_tt_srp_pipeline_matches_manual(seed):
    rng = _rng(seed)
    n, b_dim, k, d, rhat, r = 3, 2, 4, 5, 2, 3
    def cores(lead, rank, rademacher):
        out = []
        for i in range(n):
            rp = 1 if i == 0 else rank
            rn = 1 if i == n - 1 else rank
            arr = (rng.choice([-1.0, 1.0], size=(lead, rp, d, rn)) if rademacher
                   else rng.normal(size=(lead, rp, d, rn)))
            out.append(jnp.asarray(arr.astype(np.float32)))
        return out
    xc = cores(b_dim, rhat, False)
    gc = cores(k, r, True)
    codes = np.asarray(model.tt_srp(xc, gc))
    z = np.asarray(ref.tt_project_ref(xc, gc))
    np.testing.assert_array_equal(codes, (z > 0).astype(np.int32))


def test_srp_collision_rate_tracks_cosine():
    """Statistical sanity: empirical CP-SRP collision rate over K hashes is
    within a few points of 1 - theta/pi (Theorem 8) for a correlated pair."""
    rng = _rng(123)
    n, d, rhat, r, k = 3, 12, 2, 4, 4000
    xf = [rng.normal(size=(1, d, rhat)).astype(np.float32) for _ in range(n)]
    # y: perturb one factor slightly -> high cosine similarity
    yf = [x.copy() for x in xf]
    yf[0] = yf[0] + 0.1 * rng.normal(size=yf[0].shape).astype(np.float32)
    af = [jnp.asarray(rng.choice([-1.0, 1.0], size=(k, d, r)).astype(np.float32))
          for _ in range(n)]
    xj = [jnp.asarray(x) for x in xf]
    yj = [jnp.asarray(y) for y in yf]
    hx = np.asarray(model.cp_srp(xj, af))[0]
    hy = np.asarray(model.cp_srp(yj, af))[0]
    rate = float((hx == hy).mean())
    xd = np.asarray(ref.cp_materialize([x[0] for x in xf]))
    yd = np.asarray(ref.cp_materialize([y[0] for y in yf]))
    cos = float((xd * yd).sum() / (np.linalg.norm(xd) * np.linalg.norm(yd)))
    expect = 1.0 - np.arccos(np.clip(cos, -1, 1)) / np.pi
    assert abs(rate - expect) < 0.05, (rate, expect)
